//! Failure-injection tests, in both senses of the word.
//!
//! Load-time failures: the system must fail loudly and precisely, never
//! with a panic or a silent zero (missing artifacts, malformed HLO,
//! truncated calibration, junk CSV).
//!
//! Runtime failures (DESIGN.md §13): seeded board deaths, correlated
//! failure storms, link-degradation episodes and the SLO-pressure
//! autoscaler on the fleet event core. The contracts under test: no
//! request is ever lost silently (arrivals == served + explicitly
//! dropped, per model), SLO-aware routing beats round-robin on p99
//! through a storm, link degradation slows service without dropping
//! anything, the autoscaler provisions under a flash crowd and drains
//! on the trough, fault runs keep the cross-thread-count fingerprint
//! contract for every RoutingPolicy x baseline combo, and event-budget
//! exhaustion names the dead board.

use dpuconfig::coordinator::fleet::{
    AutoscaleConfig, FleetConfig, FleetCoordinator, FleetPolicy, FleetReport, FleetRequest,
    FleetScenario, FleetSpec, RoutingPolicy,
};
use dpuconfig::csvutil::Table;
use dpuconfig::data::load_models;
use dpuconfig::dpusim::DpuSim;
use dpuconfig::models::ModelVariant;
use dpuconfig::rl::Baseline;
use dpuconfig::runtime::PolicyRuntime;
use dpuconfig::workload::traffic::{ArrivalPattern, FaultProfile};
use dpuconfig::workload::WorkloadState;
use std::collections::HashMap;

// ---------------------------------------------------------------------
// Load-time failures
// ---------------------------------------------------------------------

#[test]
fn missing_artifact_names_the_fix() {
    let err = match PolicyRuntime::load(std::path::Path::new("/nonexistent/policy.hlo.txt"), 1) {
        Ok(_) => panic!("load of a missing artifact must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}

#[test]
fn malformed_hlo_is_an_error_not_a_crash() {
    let dir = std::env::temp_dir().join("dpuconfig_bad_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.hlo.txt");
    std::fs::write(&p, "HloModule garbage\nENTRY main { this is not hlo }").unwrap();
    assert!(PolicyRuntime::load(&p, 1).is_err());
    std::fs::remove_file(&p).ok();
}

#[test]
fn calibration_missing_key_is_reported_by_name() {
    let mut cal: HashMap<String, f64> = dpuconfig::data::load_calibration().unwrap();
    cal.remove("beta_mem");
    let err = match DpuSim::with_calibration(cal) {
        Ok(_) => panic!("missing calibration key must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("beta_mem"), "error must name the key: {err}");
}

#[test]
fn csv_failures_are_descriptive() {
    let err = Table::parse("").unwrap_err().to_string();
    assert!(err.contains("empty"));
    let t = Table::parse("a,b\n1,2\n").unwrap();
    let err = t.col("zzz").unwrap_err().to_string();
    assert!(err.contains("zzz"));
    let a = t.get_f64(&t.rows[0], "a").expect("numeric cell must parse");
    assert_eq!(a, 1.0);
    let bad = Table::parse("a\nxyz\n").unwrap();
    assert!(bad.get_f64(&bad.rows[0], "a").is_err());
}

#[test]
fn evaluate_rejects_unknown_model_gracefully() {
    // unknown size names and out-of-range instances error with context
    let sim = DpuSim::load().unwrap();
    let m = dpuconfig::data::load_models().unwrap().remove(0);
    let v = ModelVariant::new(m, 0.0);
    let err = sim
        .evaluate(&v, "B777", 1, WorkloadState::None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("B777"));
    let err = sim
        .evaluate(&v, "B512", 99, WorkloadState::None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("99"));
}

#[test]
fn workload_parse_rejects_junk() {
    assert!("Q".parse::<WorkloadState>().is_err());
    assert!("".parse::<WorkloadState>().is_err());
}

// ---------------------------------------------------------------------
// Runtime failures: fault-injected fleets (DESIGN.md §13)
// ---------------------------------------------------------------------

fn variant(name: &str) -> ModelVariant {
    ModelVariant::new(
        load_models()
            .unwrap()
            .into_iter()
            .find(|m| m.name == name)
            .unwrap(),
        0.0,
    )
}

fn req(name: &str, at: f64) -> FleetRequest {
    FleetRequest {
        model: variant(name),
        at_s: at,
    }
}

fn fleet(cfg: FleetConfig, baseline: Baseline) -> FleetCoordinator {
    FleetCoordinator::new(cfg, FleetPolicy::Static(baseline)).unwrap()
}

/// Fleet-level and per-model request conservation: every arrival is
/// served or explicitly dropped, with the per-model report and the
/// sampled trails telling the same story. Trails are a deterministic
/// reservoir sample since DESIGN.md §14 — the ledger lives in the
/// counters; each sampled trail must still be internally consistent
/// with the scenario, and when the sample happens to be exhaustive
/// (request count under the cap) its served/dropped split must match
/// the counters exactly.
fn assert_conserved(r: &FleetReport, scenario: &FleetScenario) {
    assert_eq!(
        r.requests_done() + r.dropped,
        r.requests_total as u64,
        "conservation broken: {} served + {} dropped != {} arrivals",
        r.requests_done(),
        r.dropped,
        r.requests_total
    );
    // the per-model latency report accounts every served request
    let reported: u64 = r.by_model.iter().map(|m| m.done).sum();
    assert_eq!(
        reported,
        r.requests_done(),
        "per-model report disagrees with board counters"
    );
    let mut arrivals: HashMap<String, u64> = HashMap::new();
    for q in &scenario.requests {
        *arrivals.entry(q.model.name()).or_default() += 1;
    }
    for m in &r.by_model {
        let n = arrivals.get(&m.model).copied().unwrap_or(0);
        assert!(m.done <= n, "{}: served {} of {} arrivals", m.model, m.done, n);
    }

    // sampled trails: bounded, sorted+unique by request id, and each one
    // physically consistent with the scenario's arrival stream
    assert!(r.trails.len() <= r.requests_total, "sample larger than the stream");
    for w in r.trails.windows(2) {
        assert!(w[0].req < w[1].req, "trails must be sorted and unique by req");
    }
    for t in &r.trails {
        assert!(t.req < scenario.requests.len(), "trail for unknown request {}", t.req);
        assert!(
            (t.at_s - scenario.requests[t.req].at_s).abs() < 1e-9,
            "request {}: trail at_s {} disagrees with arrival {}",
            t.req,
            t.at_s,
            scenario.requests[t.req].at_s
        );
        if t.done_s >= 0.0 {
            assert!(!t.dropped, "request {} both served and dropped", t.req);
            assert!(t.board < r.boards.len(), "request {} on unknown board", t.req);
            assert!(t.start_s >= t.at_s - 1e-9, "request {} started before arrival", t.req);
            assert!(t.done_s > t.start_s, "request {} done before start", t.req);
        } else {
            assert!(t.dropped, "request {} unfinished but not marked dropped", t.req);
        }
    }
    // an exhaustive sample must reproduce the ledger exactly
    if r.trails.len() == r.requests_total {
        let served = r.trails.iter().filter(|t| t.done_s >= 0.0).count() as u64;
        let lost = r.trails.iter().filter(|t| t.dropped).count() as u64;
        assert_eq!(served, r.requests_done(), "trails disagree with board counters");
        assert_eq!(lost, r.dropped, "unfinished trails must all be explicit drops");
    }
}

/// A board dying mid-frame drops nothing silently: the in-flight frame
/// is the board's loss, but the *request* backlog re-routes and every
/// arrival is accounted served or explicitly dropped — per model.
#[test]
fn board_death_mid_frame_loses_no_request() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(4).horizon_s(30.0).rate_rps(12.0).correlation(0.5).seed(7).scenario().unwrap();
    let cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::SloAware,
        seed: 7,
        // mtbf 6 s over a 30 s horizon: every board fails w.p. ~99% —
        // the test cannot pass vacuously
        faults: Some(FaultProfile {
            mtbf_s: 6.0,
            mttr_s: 4.0,
            ..FaultProfile::independent(7)
        }),
        ..FleetConfig::default()
    };
    let r = fleet(cfg, Baseline::Optimal).run(&scenario).unwrap();

    let fails: u64 = r.boards.iter().map(|b| b.fails).sum();
    assert!(fails >= 1, "fault profile must actually kill a board");
    let downtime: f64 = r.boards.iter().map(|b| b.downtime_s).sum();
    assert!(downtime > 0.0, "a death must accrue downtime");
    assert!(
        r.fleet_availability() < 1.0,
        "availability must reflect the downtime"
    );

    assert_conserved(&r, &scenario);
}

/// Under a correlated failure storm the SLO-aware router beats
/// round-robin on p99: round-robin blindly cycles requests onto
/// just-recovered cold boards (wake + full reconfiguration in the
/// request's critical path) and spreads re-routed backlog evenly, while
/// the SLO-aware router sends work where the predicted completion wait
/// actually is lowest. The fault timeline is routing-independent, so
/// both runs face byte-identical storms.
#[test]
fn slo_aware_beats_round_robin_p99_under_correlated_storm() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(4).horizon_s(40.0).rate_rps(15.0).correlation(0.7).seed(9).scenario().unwrap();
    // dense storms (mtbf 6 s, 90% hit rate) so deaths are certain and
    // the routing policies have something to disagree about
    let storm = FaultProfile {
        mtbf_s: 6.0,
        storm_hit: 0.9,
        ..FaultProfile::correlated(9)
    };
    let run = |routing: RoutingPolicy| {
        let cfg = FleetConfig {
            boards: 4,
            routing,
            seed: 9,
            faults: Some(storm.clone()),
            ..FleetConfig::default()
        };
        fleet(cfg, Baseline::Optimal).run(&scenario).unwrap()
    };
    let slo = run(RoutingPolicy::SloAware);
    let rr = run(RoutingPolicy::RoundRobin);

    let deaths = |r: &FleetReport| r.boards.iter().map(|b| b.fails).sum::<u64>();
    assert!(deaths(&slo) >= 1, "storm must kill at least one board");
    assert_eq!(
        deaths(&slo),
        deaths(&rr),
        "the fault timeline must not depend on routing"
    );
    assert_conserved(&slo, &scenario);
    assert_conserved(&rr, &scenario);

    let slo_p99 = slo.latency().p99_ms();
    let rr_p99 = rr.latency().p99_ms();
    assert!(slo_p99 > 0.0);
    assert!(
        slo_p99 < rr_p99,
        "SLO-aware p99 {slo_p99:.1} ms must beat round-robin {rr_p99:.1} ms through the storm"
    );
}

/// Link degradation (DESIGN.md §13/§14) slows boards without killing
/// them: episodes fire on every board class, no request is dropped, the
/// conservation ledger holds, and the run stays fingerprint-identical
/// across 1/2/4 worker threads for every routing policy.
#[test]
fn link_degradation_conserves_and_is_deterministic_across_threads() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(4).horizon_s(40.0).rate_rps(10.0).correlation(0.6).seed(19).scenario().unwrap();
    let mk = |routing: RoutingPolicy| {
        let cfg = FleetConfig {
            boards: 4,
            routing,
            seed: 19,
            faults: Some(FaultProfile::link(19)),
            ..FleetConfig::default()
        };
        fleet(cfg, Baseline::Optimal)
    };
    for routing in RoutingPolicy::all() {
        let r = mk(routing).run_threads(&scenario, 1).unwrap();
        let link_events: u64 = r.boards.iter().map(|b| b.link_events).sum();
        assert!(
            link_events >= 1,
            "{}: the link profile must actually degrade a link",
            routing.name()
        );
        assert_eq!(r.dropped, 0, "link degradation slows service, never kills it");
        let fails: u64 = r.boards.iter().map(|b| b.fails).sum();
        assert_eq!(fails, 0, "link faults must not register as board deaths");
        assert_conserved(&r, &scenario);
        let base = r.fingerprint();
        assert!(base.contains(":lk="), "fingerprint must carry link-event counts");
        for threads in [2, 4] {
            let fp = mk(routing).run_threads(&scenario, threads).unwrap().fingerprint();
            assert_eq!(base, fp, "{} diverges at {threads} threads", routing.name());
        }
    }
}

/// A degraded link inflates effective service time: the same scenario
/// with the link timeline enabled finishes its span no earlier, serves
/// everything, and accrues at least as much total busy time as the
/// clean run.
#[test]
fn link_degradation_inflates_service_time() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(2).horizon_s(30.0).rate_rps(12.0).correlation(0.5).seed(23).scenario().unwrap();
    let run = |faults: Option<FaultProfile>| {
        let cfg = FleetConfig {
            boards: 2,
            routing: RoutingPolicy::RoundRobin,
            seed: 23,
            faults,
            ..FleetConfig::default()
        };
        fleet(cfg, Baseline::Optimal).run(&scenario).unwrap()
    };
    let clean = run(None);
    let degraded = run(Some(FaultProfile {
        // one long, near-total degradation per board so the slowdown is
        // visible above scheduling noise
        mtbf_s: 10.0,
        mttr_s: 15.0,
        magnitude: 1.0,
        ..FaultProfile::link(23)
    }));
    assert_eq!(clean.requests_done(), degraded.requests_done());
    let busy = |r: &FleetReport| r.boards.iter().map(|b| b.totals.busy_s).sum::<f64>();
    assert!(
        busy(&degraded) > busy(&clean) + 1e-6,
        "degraded links must stretch busy time: {} vs {}",
        busy(&degraded),
        busy(&clean)
    );
    assert_conserved(&degraded, &scenario);
}

/// Flash crowd + diurnal trough for the autoscaler tests: a dense
/// request wave in [0, 10) s far beyond one board's capacity, then a
/// 1 rps trickle to the 60 s horizon (so ScaleCheck keeps beating and
/// the drain side of the policy is actually exercised).
fn flash_crowd(boards: usize) -> FleetScenario {
    let crowd = FleetSpec::new().pattern(ArrivalPattern::Steady).boards(4).horizon_s(10.0).rate_rps(200.0).correlation(0.0).seed(21).scenario().unwrap();
    let mut requests = crowd.requests;
    let mut t = 11.0;
    while t < 58.0 {
        requests.push(req("MobileNetV2", t));
        t += 1.0;
    }
    FleetScenario {
        requests,
        schedules: vec![vec![(0.0, WorkloadState::None)]; boards],
        horizon_s: 60.0,
    }
}

/// The autoscaler provisions offline boards under the flash crowd
/// (strictly fewer SLO violations than the fixed fleet it started as)
/// and drains them on the trough (drained boards park in the 0 W
/// offline state instead of burning idle watts to the horizon).
#[test]
fn autoscaler_provisions_under_flash_crowd_and_drains_on_trough() {
    // sleep disabled: any sleep seconds on boards 1..4 can only come
    // from the autoscaler's offline parking, which makes the drain
    // observable in the report
    let auto_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::SloAware,
        idle_to_sleep_s: f64::INFINITY,
        seed: 21,
        autoscale: Some(AutoscaleConfig::default()),
        ..FleetConfig::default()
    };
    let auto = fleet(auto_cfg, Baseline::Optimal)
        .run(&flash_crowd(4))
        .unwrap();
    assert_conserved(&auto, &flash_crowd(4));
    assert_eq!(auto.dropped, 0, "no faults: nothing may drop");

    // provision side: the crowd forced capacity beyond min_active
    let extra_served: u64 = auto.boards[1..].iter().map(|b| b.requests_done).sum();
    assert!(
        extra_served > 0,
        "flash crowd must force the autoscaler to provision beyond min_active"
    );

    // drain side: some provisioned board was parked again on the trough
    // (served requests AND spent a substantial slice of the horizon in
    // the 0 W offline state — impossible with sleep disabled unless the
    // autoscaler drained it)
    assert!(
        auto.boards[1..]
            .iter()
            .any(|b| b.requests_done > 0 && b.energy.sleep_s > 20.0),
        "no provisioned board was drained back to offline on the trough"
    );

    // versus the fixed fleet the autoscaler started as (min_active = 1):
    // strictly fewer SLO violations
    let fixed1_cfg = FleetConfig {
        boards: 1,
        routing: RoutingPolicy::SloAware,
        idle_to_sleep_s: f64::INFINITY,
        seed: 21,
        ..FleetConfig::default()
    };
    let fixed1 = fleet(fixed1_cfg, Baseline::Optimal)
        .run(&flash_crowd(1))
        .unwrap();
    assert!(fixed1.slo_violations() > 0, "the crowd must overwhelm one board");
    assert!(
        auto.slo_violations() < fixed1.slo_violations(),
        "autoscaler violations {} must be strictly below the fixed min-fleet's {}",
        auto.slo_violations(),
        fixed1.slo_violations()
    );

    // versus the fully-provisioned fixed fleet: the same work served,
    // but the trough idle watts of three parked boards saved
    let fixed4_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::SloAware,
        idle_to_sleep_s: f64::INFINITY,
        seed: 21,
        ..FleetConfig::default()
    };
    let fixed4 = fleet(fixed4_cfg, Baseline::Optimal)
        .run(&flash_crowd(4))
        .unwrap();
    assert_eq!(fixed4.requests_done(), auto.requests_done());
    assert!(
        auto.total_energy_j() < fixed4.total_energy_j(),
        "autoscaled fleet ({:.0} J) must undercut the always-on fleet ({:.0} J)",
        auto.total_energy_j(),
        fixed4.total_energy_j()
    );
}

/// The determinism contract survives fault injection: for every
/// RoutingPolicy x baseline combo, a faulted run's report fingerprint
/// is byte-identical across 1/2/4 worker threads.
#[test]
fn fault_fingerprints_identical_across_threads_for_every_combo() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(4).horizon_s(20.0).rate_rps(10.0).correlation(0.6).seed(13).scenario().unwrap();
    let mk = |routing: RoutingPolicy, baseline: Baseline| {
        let cfg = FleetConfig {
            boards: 4,
            routing,
            seed: 13,
            faults: Some(FaultProfile::independent(13)),
            ..FleetConfig::default()
        };
        fleet(cfg, baseline)
    };
    for routing in RoutingPolicy::all() {
        for baseline in [
            Baseline::Optimal,
            Baseline::MaxFps,
            Baseline::MinPower,
            Baseline::Random,
        ] {
            let base = mk(routing, baseline)
                .run_threads(&scenario, 1)
                .unwrap()
                .fingerprint();
            for threads in [2, 4] {
                let fp = mk(routing, baseline)
                    .run_threads(&scenario, threads)
                    .unwrap()
                    .fingerprint();
                assert_eq!(
                    base,
                    fp,
                    "{} x {} diverges at {threads} threads",
                    routing.name(),
                    baseline.name()
                );
            }
        }
    }
}

/// Faults + autoscaler together keep the contract too (the CI smoke
/// pins the same property end-to-end through the CLI).
#[test]
fn fault_plus_autoscale_fingerprints_identical_across_threads() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(4).horizon_s(25.0).rate_rps(12.0).correlation(0.6).seed(17).scenario().unwrap();
    let mk = || {
        let cfg = FleetConfig {
            boards: 4,
            routing: RoutingPolicy::SloAware,
            seed: 17,
            faults: Some(FaultProfile::correlated(17)),
            autoscale: Some(AutoscaleConfig::default()),
            ..FleetConfig::default()
        };
        fleet(cfg, Baseline::Optimal)
    };
    let base = mk().run_threads(&scenario, 1).unwrap().fingerprint();
    for threads in [2, 4] {
        let fp = mk().run_threads(&scenario, threads).unwrap().fingerprint();
        assert_eq!(base, fp, "faults+autoscale diverge at {threads} threads");
    }
}

/// Event-budget exhaustion with a permanently-dead board names the
/// board: the operator reading the error learns *why* the run could not
/// finish, not just that it ran long.
#[test]
fn event_budget_exhaustion_names_the_failed_board() {
    // every board dies almost immediately (mtbf 10 ms) and never
    // recovers; the budget is far too small for the arrival backlog
    let requests: Vec<FleetRequest> = (0..40)
        .map(|i| req("ResNet18", 1.0 + 0.05 * i as f64))
        .collect();
    let scenario = FleetScenario {
        requests,
        schedules: vec![vec![(0.0, WorkloadState::None)]; 2],
        horizon_s: 10.0,
    };
    let cfg = FleetConfig {
        boards: 2,
        routing: RoutingPolicy::LeastLoaded,
        seed: 3,
        event_budget: Some(10),
        faults: Some(FaultProfile {
            mtbf_s: 0.01,
            mttr_s: f64::INFINITY,
            ..FaultProfile::independent(3)
        }),
        ..FleetConfig::default()
    };
    let err = fleet(cfg, Baseline::Optimal)
        .run(&scenario)
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("failed and not recovered"),
        "budget error must name the dead board: {err}"
    );
    assert!(err.contains("board"), "budget error must point at a board: {err}");
}
