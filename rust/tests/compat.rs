//! Pre-builder compatibility surface (DESIGN.md §16).
//!
//! The typed [`FleetSpec`]/[`BoardSpec`] builder owns fleet
//! construction now; the positional `FleetScenario::generate` shim and
//! hand-rolled `FleetConfig` literals stay alive for downstream users.
//! These tests exercise that surface from outside the crate: the
//! deprecated entry points must compile (under `allow(deprecated)`,
//! which CI's deprecation gate sanctions only here and in the shim's
//! own module) and behave identically to the builder.

use dpuconfig::coordinator::fleet::{
    FleetConfig, FleetCoordinator, FleetPolicy, FleetScenario, FleetSpec, RoutingPolicy,
};
use dpuconfig::rl::Baseline;
use dpuconfig::workload::traffic::ArrivalPattern;

/// The deprecated positional generator is a thin forward to the
/// builder: same requests, same co-runner schedules, same horizon.
#[test]
fn deprecated_generate_is_a_thin_builder_forward() {
    #[allow(deprecated)]
    let old = FleetScenario::generate(ArrivalPattern::Bursty, 3, 18.0, 7.0, 0.6, 21).unwrap();
    let new = FleetSpec::new()
        .pattern(ArrivalPattern::Bursty)
        .boards(3)
        .horizon_s(18.0)
        .rate_rps(7.0)
        .correlation(0.6)
        .seed(21)
        .scenario()
        .unwrap();
    assert_eq!(old.horizon_s, new.horizon_s);
    assert_eq!(old.schedules, new.schedules);
    assert_eq!(old.requests.len(), new.requests.len());
    assert!(old
        .requests
        .iter()
        .zip(&new.requests)
        .all(|(a, b)| a.at_s == b.at_s && a.model.name() == b.model.name()));
}

/// A run wired entirely through the old surface — positional scenario
/// plus a hand-rolled `FleetConfig` literal — fingerprints identically
/// to the same fleet built through the typed spec.
#[test]
fn old_construction_path_runs_identically_to_the_builder() {
    #[allow(deprecated)]
    let old_scenario =
        FleetScenario::generate(ArrivalPattern::Steady, 2, 15.0, 6.0, 0.5, 4).unwrap();
    let old_cfg = FleetConfig {
        boards: 2,
        routing: RoutingPolicy::LeastLoaded,
        seed: 4,
        ..FleetConfig::default()
    };
    let old = FleetCoordinator::new(old_cfg, FleetPolicy::Static(Baseline::Optimal))
        .unwrap()
        .run(&old_scenario)
        .unwrap();

    let spec = FleetSpec::new()
        .boards(2)
        .pattern(ArrivalPattern::Steady)
        .horizon_s(15.0)
        .rate_rps(6.0)
        .correlation(0.5)
        .seed(4)
        .routing(RoutingPolicy::LeastLoaded);
    let (cfg, scenario) = spec.realize().unwrap();
    let new = FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal))
        .unwrap()
        .run(&scenario)
        .unwrap();

    assert_eq!(
        old.fingerprint(),
        new.fingerprint(),
        "builder-built fleet drifted from the legacy construction path"
    );
}
