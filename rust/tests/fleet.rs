//! Fleet event-core integration tests: event-vs-tick parity and the
//! idle-skipping speedup, the SLO story (SLO-aware routing beating
//! round-robin on p99 under bursty load), sleep-state energy economics,
//! routing/policy determinism, (artifact-gated) batched-vs-sequential
//! agent equivalence — and the sharded-executor contracts: `--threads N`
//! fingerprints byte-identical to 1 thread for every RoutingPolicy x
//! FleetPolicy combo, partition invariance under random board
//! groupings, physics parity with the single-queue path, and the
//! event-budget exhaustion error naming the stuck board.

use dpuconfig::coordinator::fleet::{
    least_loaded_pick, FleetConfig, FleetCoordinator, FleetPolicy, FleetRequest, FleetScenario, FleetSpec,
    RoutingPolicy, RunMode, SloConfig,
};
use dpuconfig::coordinator::BoardProfile;
use dpuconfig::data::load_models;
use dpuconfig::models::ModelVariant;
use dpuconfig::online::OnlineAgent;
use dpuconfig::rl::Baseline;
use dpuconfig::runtime::{default_policy_path, PolicyRuntime};
use dpuconfig::testutil::forall;
use dpuconfig::workload::traffic::ArrivalPattern;
use dpuconfig::workload::WorkloadState;

fn variant(name: &str) -> ModelVariant {
    ModelVariant::new(
        load_models()
            .unwrap()
            .into_iter()
            .find(|m| m.name == name)
            .unwrap(),
        0.0,
    )
}

fn req(name: &str, at: f64) -> FleetRequest {
    FleetRequest {
        model: variant(name),
        at_s: at,
    }
}

fn steady_schedules(boards: usize) -> Vec<Vec<(f64, WorkloadState)>> {
    vec![vec![(0.0, WorkloadState::None)]; boards]
}

fn optimal_fleet(cfg: FleetConfig) -> FleetCoordinator {
    FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap()
}

/// Tentpole acceptance #1: on a dense scenario the event-driven run and
/// the fine-tick reference must agree on total frames and energy to
/// 1e-6 (the tick grid only changes f64 summation order, never
/// semantics).
#[test]
fn event_core_matches_fine_tick_on_dense_scenario() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(2).horizon_s(30.0).rate_rps(30.0).correlation(0.7).seed(11).scenario().unwrap();
    let cfg = FleetConfig {
        boards: 2,
        tick_s: 0.05,
        routing: RoutingPolicy::LeastLoaded,
        seed: 11,
        ..FleetConfig::default()
    };
    let ev = optimal_fleet(cfg.clone())
        .run_mode(&scenario, RunMode::EventDriven)
        .unwrap();
    let tk = optimal_fleet(cfg)
        .run_mode(&scenario, RunMode::FineTick)
        .unwrap();

    assert_eq!(ev.requests_done(), tk.requests_done());
    assert_eq!(ev.requests_done() as usize, scenario.requests.len());
    assert_eq!(ev.decisions, tk.decisions, "identical decision sequences");
    let frames_rel = ((ev.total_frames() - tk.total_frames()) / tk.total_frames()).abs();
    assert!(frames_rel < 1e-6, "frames diverge: rel {frames_rel:.3e}");
    let energy_rel =
        ((ev.total_energy_j() - tk.total_energy_j()) / tk.total_energy_j()).abs();
    assert!(energy_rel < 1e-6, "energy diverges: rel {energy_rel:.3e}");
    let serving_rel =
        ((ev.serving_energy_j() - tk.serving_energy_j()) / tk.serving_energy_j()).abs();
    assert!(serving_rel < 1e-6, "serving energy diverges: rel {serving_rel:.3e}");
    // and per-request latency is identical, not just aggregates
    assert_eq!(ev.latency().fingerprint(), tk.latency().fingerprint());
}

/// Tentpole acceptance #2: on a sparse/diurnal scenario the event core
/// must execute at least 5x fewer loop iterations than the tick grid —
/// idle time costs zero events.
#[test]
fn event_core_skips_idle_on_sparse_diurnal_scenario() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Diurnal).boards(4).horizon_s(400.0).rate_rps(0.4).correlation(0.7).seed(12).scenario().unwrap();
    assert!(!scenario.requests.is_empty());
    let cfg = FleetConfig {
        boards: 4,
        tick_s: 0.05,
        routing: RoutingPolicy::EnergyAware,
        seed: 12,
        ..FleetConfig::default()
    };
    let ev = optimal_fleet(cfg.clone())
        .run_mode(&scenario, RunMode::EventDriven)
        .unwrap();
    let tk = optimal_fleet(cfg)
        .run_mode(&scenario, RunMode::FineTick)
        .unwrap();

    assert_eq!(ev.requests_done(), tk.requests_done());
    assert!(
        ev.events * 5 <= tk.events,
        "event core must run >=5x fewer iterations: {} events vs {} ticks+events",
        ev.events,
        tk.events
    );
    // parity holds on the sparse scenario too
    let frames_rel = ((ev.total_frames() - tk.total_frames()) / tk.total_frames()).abs();
    assert!(frames_rel < 1e-6, "frames diverge: rel {frames_rel:.3e}");
    let energy_rel =
        ((ev.total_energy_j() - tk.total_energy_j()) / tk.total_energy_j()).abs();
    assert!(energy_rel < 1e-6, "energy diverges: rel {energy_rel:.3e}");
}

/// Tentpole acceptance #3: the SLO-aware router beats round-robin on
/// p99 in a bursty scenario. The discriminator is warm-board awareness:
/// a request storm lands while one board is warm (configured, awake)
/// and the rest sleep; round-robin blindly spreads the storm across
/// sleepers (paying wake + full reconfiguration per board), the
/// SLO-aware router absorbs it on the warm board whose predicted queue
/// wait stays far below the wake path.
#[test]
fn slo_router_beats_round_robin_on_p99_in_bursty_storm() {
    // warmups keep board 0 configured for MobileNetV2; the storm of 12
    // requests arrives 4 s after the other boards fell asleep
    let mut requests = vec![
        req("MobileNetV2", 0.0),
        req("MobileNetV2", 3.0),
        req("MobileNetV2", 6.0),
    ];
    for i in 0..12 {
        requests.push(req("MobileNetV2", 10.0 + i as f64 * 0.001));
    }
    let scenario = FleetScenario {
        requests,
        schedules: steady_schedules(4),
        horizon_s: 30.0,
    };
    let run = |routing: RoutingPolicy| {
        let cfg = FleetConfig {
            boards: 4,
            routing,
            idle_to_sleep_s: 5.0,
            seed: 3,
            slo: SloConfig {
                default_ms: 500.0,
                per_model: vec![],
            },
            ..FleetConfig::default()
        };
        optimal_fleet(cfg).run(&scenario).unwrap()
    };
    let slo = run(RoutingPolicy::SloAware);
    let rr = run(RoutingPolicy::RoundRobin);

    assert_eq!(slo.requests_done(), 15);
    assert_eq!(rr.requests_done(), 15);
    assert_eq!(slo.dropped, 0);

    let slo_p99 = slo.latency().p99_ms();
    let rr_p99 = rr.latency().p99_ms();
    assert!(slo_p99 > 0.0);
    assert!(
        slo_p99 < rr_p99,
        "SLO-aware p99 {slo_p99:.1} ms must beat round-robin {rr_p99:.1} ms"
    );
    // the win comes from where it should: round-robin woke sleepers into
    // the storm, the SLO-aware router kept them napping
    let slo_wakes: u64 = slo.boards.iter().map(|b| b.wakes).sum();
    let rr_wakes: u64 = rr.boards.iter().map(|b| b.wakes).sum();
    assert_eq!(slo_wakes, 0, "warm board absorbs the whole storm");
    assert!(rr_wakes >= 2, "round-robin must have woken sleepers");
    // and the SLO ledger shows it: only the cold-start warmup violates
    // under SLO-aware routing, while round-robin blows the target on
    // every wake+reconfigure path
    assert!(
        slo.slo_violations() <= 2,
        "slo_aware violations: {}",
        slo.slo_violations()
    );
    assert!(
        rr.slo_violations() >= 6,
        "round_robin violations: {}",
        rr.slo_violations()
    );
    assert!(slo.slo_violations() < rr.slo_violations());
}

/// Sleep states must pay off under trough-heavy traffic: same requests,
/// same decision policy — energy-aware routing with sleep beats the
/// always-on round-robin deployment on fleet-level frames/J.
#[test]
fn sleeping_fleet_beats_always_on_fleet_under_diurnal_load() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Diurnal).boards(4).horizon_s(300.0).rate_rps(2.0).correlation(0.8).seed(17).scenario().unwrap();

    let managed_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::EnergyAware,
        idle_to_sleep_s: 5.0,
        seed: 17,
        ..FleetConfig::default()
    };
    let m = optimal_fleet(managed_cfg).run(&scenario).unwrap();

    let always_on_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::RoundRobin,
        idle_to_sleep_s: f64::INFINITY,
        seed: 17,
        ..FleetConfig::default()
    };
    let a = optimal_fleet(always_on_cfg).run(&scenario).unwrap();

    assert_eq!(
        m.requests_done(),
        a.requests_done(),
        "both fleets drain the stream"
    );
    assert!(
        m.fleet_ppw() > a.fleet_ppw(),
        "managed {:.3} fps/J must beat always-on {:.3} fps/J",
        m.fleet_ppw(),
        a.fleet_ppw()
    );
    // and the win comes from where it should: less awake-idle energy
    let m_idle: f64 = m.boards.iter().map(|b| b.energy.idle_j).sum();
    let a_idle: f64 = a.boards.iter().map(|b| b.energy.idle_j).sum();
    assert!(
        m_idle < a_idle,
        "managed idle {m_idle:.0} J vs always-on {a_idle:.0} J"
    );
}

/// Determinism satellite: same seed + scenario => identical FleetReport
/// for every RoutingPolicy x FleetPolicy combination.
#[test]
fn same_seed_same_report_for_every_routing_and_policy() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(3).horizon_s(30.0).rate_rps(8.0).correlation(0.7).seed(9).scenario().unwrap();
    let fingerprint = |routing: RoutingPolicy, policy: &str| -> String {
        let cfg = FleetConfig {
            boards: 3,
            routing,
            idle_to_sleep_s: 5.0,
            seed: 9,
            ..FleetConfig::default()
        };
        let fleet_policy = match policy {
            "optimal" => FleetPolicy::Static(Baseline::Optimal),
            "max_fps" => FleetPolicy::Static(Baseline::MaxFps),
            "min_power" => FleetPolicy::Static(Baseline::MinPower),
            "random" => FleetPolicy::Static(Baseline::Random),
            "online" => FleetPolicy::Online(Box::new(
                OnlineAgent::load_default(9).expect("committed policy weights"),
            )),
            other => panic!("unknown test policy {other}"),
        };
        FleetCoordinator::new(cfg, fleet_policy)
            .unwrap()
            .run(&scenario)
            .unwrap()
            .fingerprint()
    };
    for routing in RoutingPolicy::all() {
        for policy in ["optimal", "max_fps", "min_power", "random", "online"] {
            let a = fingerprint(routing, policy);
            let b = fingerprint(routing, policy);
            assert_eq!(
                a, b,
                "{policy} x {} must be deterministic per seed",
                routing.name()
            );
        }
    }
}

/// Determinism satellite (property half): least-loaded tie-breaking is
/// stable by board index — the minimum backlog wins and exact ties
/// resolve to the lowest index, for arbitrary backlog vectors.
#[test]
fn prop_least_loaded_tie_breaks_by_lowest_index() {
    forall(77, 300, |g, _| {
        let n = 1 + g.usize(8);
        // coarse values make ties frequent
        let backlogs: Vec<f64> = (0..n).map(|_| g.usize(4) as f64).collect();
        let pick = least_loaded_pick(&backlogs).unwrap();
        let min = backlogs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(backlogs[pick], min, "{backlogs:?} picked {pick}");
        assert!(
            backlogs[..pick].iter().all(|&b| b > min),
            "{backlogs:?}: pick {pick} is not the lowest tied index"
        );
    });
    assert_eq!(least_loaded_pick(&[]), None);
}

/// End-to-end property: under least-loaded routing, a request arriving
/// when every board is idle and empty lands on board 0 (the tie-break
/// made observable).
#[test]
fn first_request_lands_on_board_zero_under_least_loaded() {
    for seed in [1u64, 5, 23] {
        let scenario = FleetScenario {
            requests: vec![req("ResNet18", 0.0)],
            schedules: steady_schedules(3),
            horizon_s: 10.0,
        };
        let cfg = FleetConfig {
            boards: 3,
            routing: RoutingPolicy::LeastLoaded,
            seed,
            ..FleetConfig::default()
        };
        let r = optimal_fleet(cfg).run(&scenario).unwrap();
        assert_eq!(r.boards[0].requests_done, 1, "seed {seed}");
        let trail = r
            .trails
            .iter()
            .find(|t| t.req == 0)
            .expect("a one-request scenario is fully sampled");
        assert_eq!(trail.board, 0, "seed {seed}");
    }
}

/// Per-request trails are causally ordered and complete, and per-model
/// histograms partition the fleet histogram.
#[test]
fn trails_and_model_histograms_are_consistent() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(2).horizon_s(20.0).rate_rps(10.0).correlation(0.5).seed(21).scenario().unwrap();
    let cfg = FleetConfig {
        boards: 2,
        routing: RoutingPolicy::SloAware,
        seed: 21,
        ..FleetConfig::default()
    };
    let r = optimal_fleet(cfg).run(&scenario).unwrap();
    assert_eq!(r.requests_done() as usize, scenario.requests.len());
    // scenario is below the default reservoir cap: the sample is
    // exhaustive, so every request has a trail
    assert_eq!(r.trails.len(), scenario.requests.len());
    for trail in &r.trails {
        let i = trail.req;
        assert!(trail.board < 2, "request {i} routed");
        assert!(trail.at_s >= 0.0);
        assert!(trail.start_s >= trail.at_s, "request {i} starts after arrival");
        assert!(trail.done_s > trail.start_s, "request {i} finishes after start");
    }
    let by_model_total: u64 = r.by_model.iter().map(|m| m.done).sum();
    assert_eq!(by_model_total, r.requests_done());
    let by_model_viol: u64 = r.by_model.iter().map(|m| m.violations).sum();
    assert_eq!(by_model_viol, r.slo_violations());
    assert!(r.latency().count() == r.requests_done());
}

/// Tentpole acceptance: `run_threads(N)` produces a byte-identical
/// report fingerprint to `run_threads(1)` for every RoutingPolicy x
/// FleetPolicy combination — thread count is purely a speed knob.
#[test]
fn sharded_fingerprint_is_thread_count_invariant_for_every_combo() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(3).horizon_s(30.0).rate_rps(8.0).correlation(0.7).seed(9).scenario().unwrap();
    let fingerprint = |routing: RoutingPolicy, policy: &str, threads: usize| -> String {
        let cfg = FleetConfig {
            boards: 3,
            routing,
            idle_to_sleep_s: 5.0,
            seed: 9,
            ..FleetConfig::default()
        };
        let fleet_policy = match policy {
            "optimal" => FleetPolicy::Static(Baseline::Optimal),
            "max_fps" => FleetPolicy::Static(Baseline::MaxFps),
            "min_power" => FleetPolicy::Static(Baseline::MinPower),
            "random" => FleetPolicy::Static(Baseline::Random),
            "online" => FleetPolicy::Online(Box::new(
                OnlineAgent::load_default(9).expect("committed policy weights"),
            )),
            other => panic!("unknown test policy {other}"),
        };
        FleetCoordinator::new(cfg, fleet_policy)
            .unwrap()
            .run_threads(&scenario, threads)
            .unwrap()
            .fingerprint()
    };
    for routing in RoutingPolicy::all() {
        for policy in ["optimal", "max_fps", "min_power", "random", "online"] {
            let one = fingerprint(routing, policy, 1);
            let four = fingerprint(routing, policy, 4);
            assert_eq!(one, four, "{policy} x {} invariant", routing.name());
        }
    }
}

/// Tentpole acceptance (property half): arbitrary board partitions —
/// any number of shards, any grouping, any thread count — produce the
/// exact fingerprint of the 1-thread run.
#[test]
fn prop_random_board_partitions_produce_identical_fingerprints() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(5).horizon_s(25.0).rate_rps(6.0).correlation(0.7).seed(13).scenario().unwrap();
    let mk = || {
        let cfg = FleetConfig {
            boards: 5,
            routing: RoutingPolicy::SloAware,
            idle_to_sleep_s: 5.0,
            seed: 13,
            ..FleetConfig::default()
        };
        FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap()
    };
    let base = mk().run_threads(&scenario, 1).unwrap().fingerprint();
    forall(99, 8, |g, case| {
        let shard_count = 1 + g.usize(5);
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); shard_count];
        for board in 0..5 {
            let pick = g.usize(shard_count);
            groups[pick].push(board);
        }
        let threads = 1 + g.usize(4);
        let mut f = mk();
        let fp = f.run_partitioned(&scenario, &groups, threads).unwrap().fingerprint();
        assert_eq!(base, fp, "case {case}: groups {groups:?}, {threads} threads");
    });
}

/// The sharded executor is the same physical simulation as the
/// single-queue path: for an order-independent policy, every routing
/// policy yields identical frames, energy, per-board latency, wakes,
/// and decision counts (only the event-counting convention differs).
#[test]
fn sharded_executor_matches_single_queue_physics() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(3).horizon_s(25.0).rate_rps(10.0).correlation(0.6).seed(19).scenario().unwrap();
    for routing in RoutingPolicy::all() {
        let cfg = FleetConfig {
            boards: 3,
            routing,
            idle_to_sleep_s: 5.0,
            seed: 19,
            ..FleetConfig::default()
        };
        let sq = optimal_fleet(cfg.clone()).run(&scenario).unwrap();
        let sh = optimal_fleet(cfg).run_threads(&scenario, 2).unwrap();
        let name = routing.name();
        assert_eq!(sq.requests_done(), sh.requests_done(), "{name}: requests");
        assert_eq!(sq.decisions, sh.decisions, "{name}: decisions");
        assert_eq!(sq.decision_batches, sh.decision_batches, "{name}: passes");
        assert!(
            (sq.total_frames() - sh.total_frames()).abs() < 1e-9,
            "{name}: frames {} vs {}",
            sq.total_frames(),
            sh.total_frames()
        );
        let e_rel = ((sq.total_energy_j() - sh.total_energy_j()) / sq.total_energy_j()).abs();
        assert!(e_rel < 1e-9, "{name}: energy rel err {e_rel:.3e}");
        let span_diff = (sq.span_s - sh.span_s).abs();
        assert!(span_diff < 1e-9, "{name}: span {} vs {}", sq.span_s, sh.span_s);
        for (a, b) in sq.boards.iter().zip(&sh.boards) {
            assert_eq!(a.board, b.board);
            assert_eq!(a.wakes, b.wakes, "{name} board {}", a.board);
            assert_eq!(a.requests_done, b.requests_done, "{name} board {}", a.board);
            assert_eq!(a.slo_violations, b.slo_violations, "{name} board {}", a.board);
            assert_eq!(
                a.latency.fingerprint(),
                b.latency.fingerprint(),
                "{name} board {}: per-request latencies must be identical",
                a.board
            );
        }
    }
}

/// Event-budget exhaustion through the public API: both serving loops
/// honor `FleetConfig::event_budget` and the error names the stuck
/// board and its queue depth (the happy path alone used to be pinned).
#[test]
fn event_budget_err_names_stuck_board_on_both_executors() {
    let scenario = FleetScenario {
        requests: (0..20).map(|i| req("ResNet18", i as f64 * 0.01)).collect(),
        schedules: steady_schedules(2),
        horizon_s: 10.0,
    };
    let cfg = FleetConfig {
        boards: 2,
        routing: RoutingPolicy::LeastLoaded,
        seed: 3,
        event_budget: Some(8),
        ..FleetConfig::default()
    };
    let err = optimal_fleet(cfg.clone()).run(&scenario).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("event budget exhausted"), "{msg}");
    assert!(msg.contains("board"), "{msg}");
    assert!(msg.contains("queue depth"), "{msg}");

    let err = optimal_fleet(cfg.clone()).run_threads(&scenario, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("event budget exhausted"), "{msg}");
    assert!(msg.contains("board"), "{msg}");
    assert!(msg.contains("queue depth"), "{msg}");

    // the barrier-free fast path (round-robin + static policy drains
    // everything in one unbounded round) must also trip the budget —
    // enforced per board inside the drain, not just at barriers
    let mut rr = cfg;
    rr.routing = RoutingPolicy::RoundRobin;
    let err = optimal_fleet(rr).run_threads(&scenario, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("event budget exhausted"), "{msg}");
    assert!(msg.contains("board"), "{msg}");
    assert!(msg.contains("queue depth"), "{msg}");
}

fn mixed_profiles(classes: &[&str]) -> Vec<BoardProfile> {
    let sizes = dpuconfig::data::load_dpu_sizes().unwrap();
    classes
        .iter()
        .map(|c| BoardProfile::of_class(c, &sizes).unwrap())
        .collect()
}

/// Heterogeneous tentpole acceptance #1: a mixed-class fleet serves the
/// whole stream on both executors, and the sharded run's fingerprint is
/// byte-identical across thread counts for every RoutingPolicy x
/// FleetPolicy combination — heterogeneity must not cost determinism.
#[test]
fn heterogeneous_fleet_fingerprint_is_thread_invariant_for_every_combo() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Bursty).boards(3).horizon_s(30.0).rate_rps(6.0).correlation(0.7).seed(15).scenario().unwrap();
    let fingerprint = |routing: RoutingPolicy, policy: &str, threads: usize| -> String {
        let cfg = FleetConfig {
            boards: 3,
            routing,
            idle_to_sleep_s: 5.0,
            seed: 15,
            profiles: mixed_profiles(&["B512", "B1024", "B4096"]),
            ..FleetConfig::default()
        };
        let fleet_policy = match policy {
            "optimal" => FleetPolicy::Static(Baseline::Optimal),
            "max_fps" => FleetPolicy::Static(Baseline::MaxFps),
            "min_power" => FleetPolicy::Static(Baseline::MinPower),
            "random" => FleetPolicy::Static(Baseline::Random),
            "online" => FleetPolicy::Online(Box::new(
                OnlineAgent::load_default(15).expect("committed policy weights"),
            )),
            other => panic!("unknown test policy {other}"),
        };
        let r = FleetCoordinator::new(cfg, fleet_policy)
            .unwrap()
            .run_threads(&scenario, threads)
            .unwrap();
        assert_eq!(r.requests_done() as usize, scenario.requests.len());
        assert_eq!(r.dropped, 0);
        r.fingerprint()
    };
    for routing in RoutingPolicy::all() {
        for policy in ["optimal", "max_fps", "min_power", "random", "online"] {
            let one = fingerprint(routing, policy, 1);
            let four = fingerprint(routing, policy, 4);
            assert_eq!(one, four, "{policy} x {} hetero invariant", routing.name());
        }
    }
}

/// Heterogeneous tentpole acceptance #2: event-vs-tick parity holds on
/// a mixed fleet (the FineTick reference runs the same profile-aware
/// physics on the tick grid).
#[test]
fn heterogeneous_fleet_event_core_matches_fine_tick() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(3).horizon_s(30.0).rate_rps(15.0).correlation(0.6).seed(16).scenario().unwrap();
    let mk = || {
        let cfg = FleetConfig {
            boards: 3,
            tick_s: 0.05,
            routing: RoutingPolicy::SloAware,
            seed: 16,
            profiles: mixed_profiles(&["B1024", "B4096", "B512"]),
            ..FleetConfig::default()
        };
        optimal_fleet(cfg)
    };
    let ev = mk().run_mode(&scenario, RunMode::EventDriven).unwrap();
    let tk = mk().run_mode(&scenario, RunMode::FineTick).unwrap();
    assert_eq!(ev.requests_done(), tk.requests_done());
    assert_eq!(ev.decisions, tk.decisions);
    let frames_rel = ((ev.total_frames() - tk.total_frames()) / tk.total_frames()).abs();
    assert!(frames_rel < 1e-6, "hetero frames diverge: rel {frames_rel:.3e}");
    let energy_rel = ((ev.total_energy_j() - tk.total_energy_j()) / tk.total_energy_j()).abs();
    assert!(energy_rel < 1e-6, "hetero energy diverges: rel {energy_rel:.3e}");
    // and the board classes surface in the report
    assert_eq!(ev.boards[0].class, "B1024");
    assert_eq!(ev.boards[2].class, "B512");
}

/// Per-board service estimates make the SLO router heterogeneity-aware:
/// with a B512-class and a B4096-class board both awake, a spaced
/// ResNet152 stream lands entirely on the big board (its predicted
/// completion wait is far lower).
#[test]
fn slo_router_prefers_capable_boards_for_heavy_models() {
    let scenario = FleetScenario {
        requests: (0..6).map(|i| req("ResNet152", i as f64 * 3.0)).collect(),
        schedules: steady_schedules(2),
        horizon_s: 30.0,
    };
    let cfg = FleetConfig {
        boards: 2,
        routing: RoutingPolicy::SloAware,
        idle_to_sleep_s: f64::INFINITY,
        seed: 4,
        profiles: mixed_profiles(&["B512", "B4096"]),
        ..FleetConfig::default()
    };
    let r = optimal_fleet(cfg).run(&scenario).unwrap();
    assert_eq!(r.requests_done(), 6);
    assert_eq!(
        r.boards[1].requests_done, 6,
        "every ResNet152 belongs on the B4096-class board"
    );
    assert_eq!(r.boards[0].requests_done, 0);
}

/// Fabric caps are physical: a B512-class-only fleet still serves heavy
/// models (decisions project onto its allowed action subset) but pays
/// for it with a worse tail than the reference class.
#[test]
fn restricted_fabric_serves_with_worse_tail_latency() {
    let scenario = FleetScenario {
        requests: (0..5).map(|i| req("ResNet152", i as f64 * 4.0)).collect(),
        schedules: steady_schedules(1),
        horizon_s: 30.0,
    };
    let run = |classes: &[&str]| {
        let cfg = FleetConfig {
            boards: 1,
            routing: RoutingPolicy::RoundRobin,
            idle_to_sleep_s: f64::INFINITY,
            seed: 8,
            profiles: mixed_profiles(classes),
            ..FleetConfig::default()
        };
        optimal_fleet(cfg).run(&scenario).unwrap()
    };
    let small = run(&["B512"]);
    let big = run(&["B4096"]);
    assert_eq!(small.requests_done(), 5);
    assert_eq!(big.requests_done(), 5);
    // max_ms is exact (tracked outside the buckets), so the ~2% tail gap
    // between the classes can't alias into one log-linear bucket
    assert!(
        small.latency().max_ms() > big.latency().max_ms(),
        "B512-class tail {:.1} ms must exceed B4096-class {:.1} ms on ResNet152",
        small.latency().max_ms(),
        big.latency().max_ms()
    );
    assert!(small.boards[0].totals.busy_s > big.boards[0].totals.busy_s);
}

/// Config validation: a profile list that doesn't match the board count
/// is rejected up front.
#[test]
fn mismatched_profile_count_is_rejected() {
    let cfg = FleetConfig {
        boards: 3,
        profiles: mixed_profiles(&["B512", "B4096"]),
        ..FleetConfig::default()
    };
    let err = FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap_err();
    assert!(format!("{err:#}").contains("board profiles"), "{err:#}");
}

/// Batched fleet decisions must agree with the sequential agent
/// (requires `make artifacts`). Simultaneous arrivals form same-instant
/// decision cohorts, so the batched artifact uses no more forward
/// passes than the sequential one while choosing identical actions.
#[test]
fn batched_fleet_decisions_match_sequential_agent() {
    if !default_policy_path(8).exists() || !default_policy_path(1).exists() {
        eprintln!("SKIP: policy artifacts missing — run `make artifacts`");
        return;
    }
    // six different models arriving at the same instant on six boards:
    // one decision cohort per wave
    let names = [
        "ResNet18",
        "ResNet50",
        "MobileNetV2",
        "InceptionV3",
        "ResNet152",
        "ResNeXt50_32x4d",
    ];
    let mut requests = Vec::new();
    for wave in 0..4 {
        for name in names {
            requests.push(req(name, wave as f64 * 5.0));
        }
    }
    let scenario = FleetScenario {
        requests,
        schedules: steady_schedules(6),
        horizon_s: 40.0,
    };
    let run_with = |batch: usize| {
        let rt = PolicyRuntime::load(&default_policy_path(batch), batch).unwrap();
        let cfg = FleetConfig {
            boards: 6,
            routing: RoutingPolicy::RoundRobin,
            seed: 5,
            ..FleetConfig::default()
        };
        FleetCoordinator::new(cfg, FleetPolicy::Agent(rt))
            .unwrap()
            .run(&scenario)
            .unwrap()
    };
    let batched = run_with(8);
    let sequential = run_with(1);
    assert_eq!(batched.decisions, sequential.decisions);
    assert!(
        batched.decision_batches <= sequential.decision_batches,
        "batched {} passes vs sequential {}",
        batched.decision_batches,
        sequential.decision_batches
    );
    let bf = batched.total_frames();
    let sf = sequential.total_frames();
    assert!(
        ((bf - sf) / sf).abs() < 1e-6,
        "identical decisions must serve identical frames: {bf} vs {sf}"
    );
}
