//! Fleet-coordinator integration tests: aggregate-efficiency parity with
//! independent single-board runs, the energy story of sleep states, and
//! (artifact-gated) batched-vs-sequential agent equivalence.

use dpuconfig::coordinator::fleet::{
    FleetConfig, FleetCoordinator, FleetJob, FleetPolicy, FleetScenario, RoutingPolicy,
};
use dpuconfig::coordinator::{Arrival, Coordinator, Scenario, Selector};
use dpuconfig::data::load_models;
use dpuconfig::models::ModelVariant;
use dpuconfig::rl::Baseline;
use dpuconfig::runtime::{default_policy_path, PolicyRuntime};
use dpuconfig::workload::traffic::ArrivalPattern;
use dpuconfig::workload::WorkloadState;

fn variant(name: &str) -> ModelVariant {
    ModelVariant::new(
        load_models()
            .unwrap()
            .into_iter()
            .find(|m| m.name == name)
            .unwrap(),
        0.0,
    )
}

/// The satellite acceptance test: a 4-board fleet under uncorrelated,
/// pre-partitioned load must land within tolerance of 4 independent
/// single-board coordinator runs on aggregate energy efficiency.
#[test]
fn four_board_fleet_matches_independent_single_board_runs() {
    let mix = ["ResNet18", "MobileNetV2", "InceptionV3", "ResNet50"];
    let groups = 8usize;
    let slot_s = 20.0;

    // fleet: groups of 4 simultaneous jobs, round-robin -> board i always
    // serves model mix[(k + i) % 4]
    let mut jobs = Vec::new();
    for k in 0..groups {
        for i in 0..4 {
            jobs.push(FleetJob {
                model: variant(mix[(k + i) % 4]),
                at_s: k as f64 * slot_s,
                duration_s: slot_s,
            });
        }
    }
    let scenario = FleetScenario {
        jobs,
        schedules: vec![vec![(0.0, WorkloadState::None)]; 4],
        horizon_s: groups as f64 * slot_s,
    };
    let cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::RoundRobin,
        idle_to_sleep_s: f64::INFINITY,
        ..FleetConfig::default()
    };
    let mut fleet = FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap();
    let fleet_report = fleet.run(&scenario).unwrap();
    assert_eq!(fleet_report.jobs_done(), (groups * 4) as u64);

    // the same load as 4 independent single-board scenarios
    let mut frames = 0.0;
    let mut energy = 0.0;
    for i in 0..4 {
        let arrivals: Vec<Arrival> = (0..groups)
            .map(|k| Arrival {
                model: variant(mix[(k + i) % 4]),
                at_s: k as f64 * slot_s,
                duration_s: slot_s,
            })
            .collect();
        let s = Scenario {
            arrivals,
            workload: vec![(0.0, WorkloadState::None)],
            seed: 1,
        };
        let mut c = Coordinator::new(Selector::Static(Baseline::Optimal), 1).unwrap();
        let r = c.run_scenario(&s).unwrap();
        frames += r.totals.frames;
        energy += r.totals.energy_fpga_j;
    }
    let single_ppw = frames / energy;
    let fleet_ppw = fleet_report.serving_ppw();
    let rel = (fleet_ppw / single_ppw - 1.0).abs();
    assert!(
        rel < 0.15,
        "fleet {fleet_ppw:.3} vs 4x single-board {single_ppw:.3} fps/J (rel {rel:.3})"
    );
}

/// Sleep states must pay off under trough-heavy traffic: same jobs, same
/// decision policy — energy-aware routing with sleep beats the
/// always-on round-robin deployment on fleet-level frames/J.
#[test]
fn sleeping_fleet_beats_always_on_fleet_under_diurnal_load() {
    let scenario =
        FleetScenario::generate(ArrivalPattern::Diurnal, 4, 300.0, 0.25, 8.0, 0.8, 17).unwrap();

    let managed_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::EnergyAware,
        idle_to_sleep_s: 5.0,
        seed: 17,
        ..FleetConfig::default()
    };
    let mut managed =
        FleetCoordinator::new(managed_cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap();
    let m = managed.run(&scenario).unwrap();

    let always_on_cfg = FleetConfig {
        boards: 4,
        routing: RoutingPolicy::RoundRobin,
        idle_to_sleep_s: f64::INFINITY,
        seed: 17,
        ..FleetConfig::default()
    };
    let mut always_on =
        FleetCoordinator::new(always_on_cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap();
    let a = always_on.run(&scenario).unwrap();

    assert_eq!(m.jobs_done(), a.jobs_done(), "both fleets drain the stream");
    assert!(
        m.fleet_ppw() > a.fleet_ppw(),
        "managed {:.3} fps/J must beat always-on {:.3} fps/J",
        m.fleet_ppw(),
        a.fleet_ppw()
    );
    // and the win comes from where it should: less awake-idle energy
    let m_idle: f64 = m.boards.iter().map(|b| b.energy.idle_j).sum();
    let a_idle: f64 = a.boards.iter().map(|b| b.energy.idle_j).sum();
    assert!(m_idle < a_idle, "managed idle {m_idle:.0} J vs always-on {a_idle:.0} J");
}

/// Batched fleet decisions must agree with the sequential agent and use
/// fewer forward passes (requires `make artifacts`).
#[test]
fn batched_fleet_decisions_match_sequential_agent() {
    if !default_policy_path(8).exists() || !default_policy_path(1).exists() {
        eprintln!("SKIP: policy artifacts missing — run `make artifacts`");
        return;
    }
    let scenario =
        FleetScenario::generate(ArrivalPattern::Steady, 6, 60.0, 0.5, 6.0, 0.5, 5).unwrap();
    let run_with = |batch: usize| {
        let rt = PolicyRuntime::load(&default_policy_path(batch), batch).unwrap();
        let cfg = FleetConfig {
            boards: 6,
            routing: RoutingPolicy::RoundRobin,
            seed: 5,
            ..FleetConfig::default()
        };
        let mut fleet = FleetCoordinator::new(cfg, FleetPolicy::Agent(rt)).unwrap();
        fleet.run(&scenario).unwrap()
    };
    let batched = run_with(8);
    let sequential = run_with(1);
    assert_eq!(batched.decisions, sequential.decisions);
    assert!(
        batched.decision_batches < sequential.decision_batches,
        "batched {} passes vs sequential {}",
        batched.decision_batches,
        sequential.decision_batches
    );
    let bf = batched.total_frames();
    let sf = sequential.total_frames();
    assert!(
        ((bf - sf) / sf).abs() < 1e-6,
        "identical decisions must serve identical frames: {bf} vs {sf}"
    );
}
