//! End-to-end runtime tests: the AOT policy artifact loads via PJRT and
//! the full L3 decision path (telemetry -> featurize -> PJRT -> action)
//! reproduces the python-side agent behaviour.
//!
//! Requires `make artifacts`; tests skip (with a loud message) if the
//! artifacts are missing so `cargo test` stays runnable standalone.

use dpuconfig::coordinator::{Coordinator, DecisionService, Selector};
use dpuconfig::data::load_policy_meta;
use dpuconfig::dpusim::DpuSim;
use dpuconfig::eval::fig5;
use dpuconfig::models::load_variants;
use dpuconfig::rl::Featurizer;
use dpuconfig::runtime::{default_policy_path, PolicyRuntime, NUM_ACTIONS};
use dpuconfig::telemetry::{PlatformState, Sampler};
use dpuconfig::workload::WorkloadState;
use std::time::Duration;

fn artifacts_present() -> bool {
    let ok = default_policy_path(1).exists();
    if !ok {
        eprintln!("SKIP: artifacts/policy.hlo.txt missing — run `make artifacts`");
    }
    ok
}

#[test]
fn policy_loads_and_infers() {
    if !artifacts_present() {
        return;
    }
    let rt = PolicyRuntime::load(&default_policy_path(1), 1).unwrap();
    let obs = [0.5f32; 22];
    let out = rt.infer(&obs).unwrap();
    assert_eq!(out.logits.len(), NUM_ACTIONS);
    assert!(out.logits.iter().all(|l| l.is_finite()));
    assert!(out.value.is_finite());
    // determinism
    let out2 = rt.infer(&obs).unwrap();
    assert_eq!(out.logits, out2.logits);
}

#[test]
fn batched_artifact_matches_single() {
    if !artifacts_present() {
        return;
    }
    let rt1 = PolicyRuntime::load(&default_policy_path(1), 1).unwrap();
    let rt8 = PolicyRuntime::load(&default_policy_path(8), 8).unwrap();
    let sim = DpuSim::load().unwrap();
    let featurizer = Featurizer::new();
    let mut sampler = Sampler::from_calibration(3, sim.calibration());
    let variants = load_variants().unwrap();
    let obs: Vec<[f32; 22]> = variants
        .iter()
        .take(8)
        .map(|v| {
            let p = PlatformState {
                workload: WorkloadState::Cpu,
                dpu_traffic_bps: 0.0,
                host_cpu_util: 0.0,
                p_fpga: 2.2,
                p_arm: 1.5,
            };
            featurizer.observe(&sampler.sample(0, &p), v)
        })
        .collect();
    let batched = rt8.infer_batch(&obs).unwrap();
    for (o, b) in obs.iter().zip(&batched) {
        let single = rt1.infer(o).unwrap();
        assert_eq!(
            single.argmax(),
            b.argmax(),
            "batched and single artifacts must agree"
        );
        for (x, y) in single.logits.iter().zip(&b.logits) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}

#[test]
fn agent_fig5_matches_paper_band() {
    // the paper's headline: the agent achieves ~95% (avg) of optimal PPW
    // on the held-out models; static baselines fall far short.
    if !artifacts_present() {
        return;
    }
    let sim = DpuSim::load().unwrap();
    let rt = PolicyRuntime::load(&default_policy_path(1), 1).unwrap();
    let mut engine = dpuconfig::coordinator::DecisionEngine::new(Selector::Agent(rt), 5);
    let (_, summaries) = fig5::run(
        &sim,
        &mut engine,
        &[WorkloadState::None, WorkloadState::Cpu, WorkloadState::Mem],
        5,
    )
    .unwrap();
    let avg: f64 =
        summaries.iter().map(|s| s.agent_avg).sum::<f64>() / summaries.len() as f64;
    assert!(
        avg > 0.90,
        "agent average normalized PPW {avg:.3} below the reproduction band"
    );
    for s in &summaries {
        assert!(
            s.agent_avg > s.maxfps_avg - 0.05,
            "[{}] agent {:.3} should not lose to maxFPS {:.3}",
            s.state,
            s.agent_avg,
            s.maxfps_avg
        );
        assert!(s.agent_avg > s.minpower_avg, "[{}] vs minpower", s.state);
    }
    // constraint satisfaction across C+M: close to the paper's 16/18 (89%)
    let met: usize = summaries
        .iter()
        .filter(|s| s.state != "N")
        .map(|s| s.constraint_met)
        .sum();
    assert!(met >= 14, "constraint met {met}/18 across C+M");
}

#[test]
fn agent_scenario_end_to_end() {
    // full coordinator loop with the real PJRT policy
    if !artifacts_present() {
        return;
    }
    let rt = PolicyRuntime::load(&default_policy_path(1), 1).unwrap();
    let mut coord = Coordinator::new(Selector::Agent(rt), 7).unwrap();
    let report = coord
        .run_scenario(&dpuconfig::eval::timeline::fig6_scenario(20.0).unwrap())
        .unwrap();
    assert_eq!(report.policy, "dpuconfig");
    assert!(report.totals.frames > 100.0);
    assert!(report.totals.avg_ppw() > 1.0);
}

#[test]
fn decision_service_concurrent_clients() {
    if !artifacts_present() {
        return;
    }
    let service =
        DecisionService::spawn(default_policy_path(8), 8, Duration::from_millis(1)).unwrap();
    let mut handles = Vec::new();
    for i in 0..24 {
        let client = service.client();
        handles.push(std::thread::spawn(move || {
            let mut obs = [0.1f32; 22];
            obs[16] = (i % 12) as f32; // vary GMAC
            client.decide(obs).map(|o| o.argmax())
        }));
    }
    for h in handles {
        let a = h.join().unwrap().unwrap();
        assert!(a < NUM_ACTIONS);
    }
}

#[test]
fn meta_matches_runtime_dims() {
    if !artifacts_present() {
        return;
    }
    let meta = load_policy_meta().unwrap();
    assert_eq!(meta.get("obs_dim").map(String::as_str), Some("22"));
    assert_eq!(meta.get("num_actions").map(String::as_str), Some("26"));
}
