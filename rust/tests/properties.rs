//! Property-based tests (mini-proptest harness, rust/src/testutil.rs) on
//! the coordinator's invariants: decision routing, reconfiguration state,
//! scenario time accounting, reward bounds, dpusim physical laws — and
//! the fault-injection laws of DESIGN.md §13 (deaths only ever cost
//! frames and energy; availability is a true fraction).

use dpuconfig::coordinator::fleet::{
    AutoscaleConfig, BoardSpec, FleetConfig, FleetCoordinator, FleetPolicy, FleetSpec,
    RoutingPolicy,
};
use dpuconfig::coordinator::{Arrival, Coordinator, Event, ReconfigManager, Scenario, Selector};
use dpuconfig::dpusim::{DpuSim, FPS_CONSTRAINT};
use dpuconfig::rl::reward::{Outcome, RewardCalculator};
use dpuconfig::rl::{Baseline, Featurizer};
use dpuconfig::telemetry::{PlatformState, Sampler};
use dpuconfig::testutil::forall;
use dpuconfig::workload::traffic::{ArrivalPattern, FaultProfile};
use dpuconfig::workload::WorkloadState;

#[test]
fn prop_optimal_action_is_feasible_when_anything_is() {
    let sim = DpuSim::load().unwrap();
    forall(101, 150, |g, _| {
        let v = g.variant();
        let st = g.state();
        let rows = sim.sweep_variant(&v, st).unwrap();
        let opt = sim.optimal_action(&v, st).unwrap();
        let any_feasible = rows.iter().any(|r| r.meets_constraint);
        if any_feasible {
            assert!(
                rows[opt].meets_constraint,
                "{} [{st}]: optimal {} violates the constraint while feasible configs exist",
                v.name(),
                sim.actions()[opt].notation()
            );
        }
        // optimal dominates every same-feasibility row on PPW
        for (i, r) in rows.iter().enumerate() {
            if r.meets_constraint == rows[opt].meets_constraint || !any_feasible {
                assert!(rows[opt].ppw >= r.ppw - 1e-12, "action {i} beats optimal");
            }
        }
    });
}

#[test]
fn prop_more_instances_more_power() {
    // power must be monotone in instance count (same size, model, state)
    let sim = DpuSim::load().unwrap();
    forall(102, 150, |g, _| {
        let v = g.variant();
        let st = g.state();
        let sizes = sim.sizes();
        let size = {
            let names: Vec<&String> = sizes.keys().collect();
            names[g.usize(names.len())].clone()
        };
        let max_n = sizes[&size].max_instances;
        let mut prev = 0.0;
        for n in 1..=max_n {
            let m = sim.evaluate(&v, &size, n, st).unwrap();
            assert!(
                m.p_fpga > prev,
                "{} {}x{n} [{st}]: power {} not > {prev}",
                v.name(),
                size,
                m.p_fpga
            );
            prev = m.p_fpga;
        }
    });
}

#[test]
fn prop_aggregate_fps_bounded_by_linear_scaling() {
    // aggregate fps never exceeds n x single-instance (no free lunch);
    // it CAN drop below a single instance under heavy burst contention
    // (DDR thrashing with 4+ big DPUs), so only the upper bound and
    // positivity are invariant.
    let sim = DpuSim::load().unwrap();
    forall(103, 150, |g, _| {
        let v = g.variant();
        let st = g.state();
        let a = g.action();
        let f1 = sim.evaluate(&v, &a.size, 1, st).unwrap().fps;
        let fn_ = sim.evaluate(&v, &a.size, a.instances, st).unwrap().fps;
        assert!(fn_ <= a.instances as f64 * f1 + 1e-9, "{} {}", v.name(), a.notation());
        assert!(fn_ > 0.0, "{} {}", v.name(), a.notation());
    });
}

#[test]
fn prop_extra_traffic_zero_is_identity() {
    // the multi-tenant entry point with zero foreign traffic must be
    // bit-identical to the single-tenant evaluate (python-parity safety)
    let sim = DpuSim::load().unwrap();
    forall(110, 150, |g, _| {
        let v = g.variant();
        let st = g.state();
        let a = g.action();
        let m1 = sim.evaluate(&v, &a.size, a.instances, st).unwrap();
        let m2 = sim
            .evaluate_with_extra_traffic(&v, &a.size, a.instances, st, 0.0)
            .unwrap();
        assert_eq!(m1, m2, "{} {}", v.name(), a.notation());
    });
}

#[test]
fn prop_foreign_traffic_monotonically_hurts() {
    let sim = DpuSim::load().unwrap();
    forall(111, 150, |g, _| {
        let v = g.variant();
        let st = g.state();
        let a = g.action();
        let mut prev = f64::INFINITY;
        for extra in [0.0, 1e9, 3e9, 6e9] {
            let m = sim
                .evaluate_with_extra_traffic(&v, &a.size, a.instances, st, extra)
                .unwrap();
            assert!(
                m.fps <= prev + 1e-9,
                "{} {} extra={extra}: fps {} > prev {prev}",
                v.name(),
                a.notation(),
                m.fps
            );
            prev = m.fps;
        }
    });
}

#[test]
fn prop_reward_always_in_unit_interval() {
    forall(104, 300, |g, _| {
        let mut rc = RewardCalculator::new();
        for _ in 0..20 {
            let r = rc.calculate(&Outcome {
                measured_fps: g.f64(1.0, 2000.0),
                fpga_power: g.f64(0.5, 30.0),
                cpu_util: g.f64(0.0, 100.0),
                mem_util_gbs: g.f64(0.0, 15.0),
                gmac: g.f64(0.05, 13.0),
                model_data_mb: g.f64(1.0, 200.0),
                fps_constraint: FPS_CONSTRAINT,
            });
            assert!((-1.0..=1.0).contains(&r), "reward {r} out of bounds");
        }
    });
}

#[test]
fn prop_reconfig_charges_iff_state_changes() {
    // ReconfigManager: heavy phases charged exactly when (dpu, model) change
    let sim = DpuSim::load().unwrap();
    forall(105, 200, |g, _| {
        let mut mgr = ReconfigManager::new();
        let mut last: Option<(usize, String)> = None;
        for _ in 0..12 {
            let a = g.action();
            let v = g.variant();
            let ov = mgr.apply(&sim.actions()[a.id], &v.name());
            match &last {
                None => {
                    assert!(ov.reconfig_us > 0 && ov.instr_load_us > 0);
                }
                Some((la, lm)) => {
                    assert_eq!(ov.reconfig_us > 0, *la != a.id);
                    assert_eq!(ov.instr_load_us > 0, *la != a.id || *lm != v.name());
                }
            }
            // telemetry + RL inference always charged
            assert_eq!(ov.telemetry_us, 88_000);
            assert_eq!(ov.rl_inference_us, 20_000);
            last = Some((a.id, v.name()));
        }
    });
}

#[test]
fn prop_scenario_time_is_conserved() {
    // busy + overhead == wall time of the scenario (up to the final
    // overhead possibly spilling past the end)
    forall(106, 40, |g, _| {
        let dur = g.f64(5.0, 30.0);
        let n_models = 1 + g.usize(3);
        let mut arrivals = Vec::new();
        for i in 0..n_models {
            arrivals.push(Arrival {
                model: g.variant(),
                at_s: i as f64 * dur,
                duration_s: dur,
            });
        }
        let wall = n_models as f64 * dur;
        let scenario = Scenario {
            arrivals,
            workload: vec![
                (0.0, WorkloadState::None),
                (g.f64(1.0, wall.max(2.0)), g.state()),
            ],
            seed: 1,
        };
        let mut c = Coordinator::new(Selector::Static(Baseline::Optimal), 1).unwrap();
        let r = c.run_scenario(&scenario).unwrap();
        let covered = r.totals.busy_s + r.totals.overhead_s;
        assert!(
            (covered - wall).abs() < 1.1,
            "covered {covered} vs wall {wall}"
        );
        // events are time-ordered
        let mut last_t = -1.0;
        for e in &r.events {
            let t = match e {
                Event::Decision { t_s, .. } => *t_s,
                Event::Serve { t_s, .. } => *t_s,
            };
            assert!(t >= last_t - 1e-9, "events out of order");
            last_t = t;
        }
    });
}

#[test]
fn prop_featurizer_is_pure() {
    // same sample + model => identical observation (no hidden state)
    let f = Featurizer::new();
    let sim = DpuSim::load().unwrap();
    forall(107, 100, |g, _| {
        let v = g.variant();
        let st = g.state();
        let mut sampler = Sampler::from_calibration(9, sim.calibration());
        let p = PlatformState {
            workload: st,
            dpu_traffic_bps: g.f64(0.0, 5e9),
            host_cpu_util: g.f64(0.0, 50.0),
            p_fpga: g.f64(2.0, 15.0),
            p_arm: g.f64(1.0, 5.0),
        };
        let s = sampler.sample(0, &p);
        let o1 = f.observe(&s, &v);
        let o2 = f.observe(&s, &v);
        assert_eq!(o1, o2);
        assert!(o1.iter().all(|x| x.is_finite()));
    });
}

#[test]
fn prop_baselines_agree_with_sweep_extremes() {
    let sim = DpuSim::load().unwrap();
    forall(108, 100, |g, _| {
        let v = g.variant();
        let st = g.state();
        let rows = sim.sweep_variant(&v, st).unwrap();
        let maxf = Baseline::MaxFps.select(&sim, &v, st, None).unwrap();
        let minp = Baseline::MinPower.select(&sim, &v, st, None).unwrap();
        for r in &rows {
            assert!(rows[maxf].fps >= r.fps - 1e-12);
            assert!(rows[minp].p_fpga <= r.p_fpga + 1e-12);
        }
    });
}

#[test]
fn prop_speculative_sharded_fingerprint_matches_single_queue() {
    // DESIGN.md §15: speculative admission must be invisible in the
    // report. For the state-dependent routers (the policies that used to
    // barrier at every arrival), any random partition × thread count
    // must reproduce the single-queue fingerprint — including the |sfp=
    // stream digest — byte for byte, with deaths or link degradation
    // plus the autoscaler all active.
    forall(121, 6, |g, _| {
        let seed = 1 + g.usize(1_000_000) as u64;
        let horizon = g.f64(15.0, 25.0);
        let rate = g.f64(4.0, 8.0);
        let boards = 6;
        let pattern = if g.bool() {
            ArrivalPattern::Steady
        } else {
            ArrivalPattern::Bursty
        };
        let scenario =
            FleetSpec::new().pattern(pattern).boards(boards).horizon_s(horizon).rate_rps(rate).correlation(0.4).seed(seed).scenario().unwrap();
        let faults = if g.bool() {
            FaultProfile::link(seed)
        } else {
            FaultProfile::correlated(seed)
        };
        // random partition of the fleet into 1..=4 non-empty shards
        let shard_count = 1 + g.usize(4);
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); shard_count];
        for b in 0..boards {
            groups[g.usize(shard_count)].push(b);
        }
        groups.retain(|gr| !gr.is_empty());
        let threads = 1 + g.usize(4);
        for routing in [RoutingPolicy::SloAware, RoutingPolicy::LeastLoaded] {
            let mk = || {
                let cfg = FleetConfig {
                    boards,
                    routing,
                    seed,
                    faults: Some(faults.clone()),
                    autoscale: Some(AutoscaleConfig::default()),
                    ..FleetConfig::default()
                };
                FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap()
            };
            let single = mk().run(&scenario).unwrap();
            assert_eq!(
                (single.spec_routes, single.spec_conflicts, single.spec_redrains),
                (0, 0, 0),
                "the single-queue path never speculates"
            );
            let sharded = mk().run_partitioned(&scenario, &groups, threads).unwrap();
            assert_eq!(
                single.fingerprint(),
                sharded.fingerprint(),
                "{routing:?} diverged on groups {groups:?} x {threads} threads (seed {seed})"
            );
            assert_eq!(
                sharded.spec_conflicts, 0,
                "speculation conflicts are impossible by construction"
            );
        }
    });
}

#[test]
fn prop_faults_only_ever_cost_frames_and_energy() {
    // Against the fault-free run of the same scenario + seed, any
    // death-dealing fault profile can only lose served frames (dropped
    // requests) and energy (dead boards draw 0 W, and with sleep
    // disabled the fault-free fleet burns idle watts in their place);
    // per-board availability is a true fraction; conservation holds.
    forall(120, 6, |g, _| {
        let seed = 1 + g.usize(1_000_000) as u64;
        let horizon = g.f64(25.0, 40.0);
        let rate = g.f64(3.0, 8.0);
        let pattern = if g.bool() {
            ArrivalPattern::Steady
        } else {
            ArrivalPattern::Bursty
        };
        let scenario = FleetSpec::new().pattern(pattern).boards(4).horizon_s(horizon).rate_rps(rate).correlation(0.3).seed(seed).scenario().unwrap();
        let mk = |faults: Option<FaultProfile>| {
            let cfg = FleetConfig {
                boards: 4,
                routing: RoutingPolicy::LeastLoaded,
                idle_to_sleep_s: f64::INFINITY,
                seed,
                faults,
                ..FleetConfig::default()
            };
            FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap()
        };

        let free = mk(None).run(&scenario).unwrap();
        assert_eq!(free.dropped, 0, "fault-free runs never drop");
        for b in &free.boards {
            assert!((b.availability - 1.0).abs() < 1e-12, "fault-free availability");
        }

        // repair times well above the reconfiguration scale, so the 0 W
        // downtime always outweighs the re-route/recovery overheads
        let profile = if g.bool() {
            FaultProfile {
                mtbf_s: g.f64(8.0, 25.0),
                mttr_s: g.f64(8.0, 20.0),
                ..FaultProfile::independent(seed)
            }
        } else {
            FaultProfile {
                mtbf_s: g.f64(8.0, 25.0),
                mttr_s: g.f64(8.0, 20.0),
                storm_hit: g.f64(0.3, 0.8),
                ..FaultProfile::correlated(seed)
            }
        };
        let faulted = mk(Some(profile)).run(&scenario).unwrap();
        assert_eq!(
            faulted.requests_done() + faulted.dropped,
            faulted.requests_total as u64,
            "conservation under faults"
        );
        for b in &faulted.boards {
            assert!(
                (0.0..=1.0).contains(&b.availability),
                "board {} availability {} out of [0,1]",
                b.board,
                b.availability
            );
            assert!(b.downtime_s >= 0.0);
        }
        assert!(
            faulted.total_frames() <= free.total_frames() + 1e-9,
            "faults must not mint frames: {} > {}",
            faulted.total_frames(),
            free.total_frames()
        );
        // Slack covers the one legitimate corner: a death clipped by the
        // horizon (fail in the run's final moments) re-serves its
        // in-flight frame elsewhere (~1 J of switch + serve overhead)
        // while the 0 W downtime that normally dwarfs it got truncated.
        // Any un-clipped death saves >= mttr_s * p_pl_static ~ 12 J.
        assert!(
            faulted.total_energy_j() <= free.total_energy_j() + 2.5,
            "faults must not mint energy: {} J > {} J",
            faulted.total_energy_j(),
            free.total_energy_j()
        );

        // thermal derating slows and heats but never kills: everything
        // is served, nothing drops, availability stays 1.0
        let thermal = mk(Some(FaultProfile {
            mtbf_s: g.f64(5.0, 15.0),
            ..FaultProfile::thermal(seed)
        }))
        .run(&scenario)
        .unwrap();
        assert_eq!(thermal.dropped, 0, "thermal derating never drops requests");
        assert_eq!(thermal.requests_done() as usize, thermal.requests_total);
        for b in &thermal.boards {
            assert_eq!(b.fails, 0, "thermal derating never kills a board");
            assert!((b.availability - 1.0).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_indexed_routing_matches_the_scan_oracle() {
    // The incremental route index (DESIGN.md §17) must be answer-
    // identical to the O(B·Q) scan router: byte-identical fingerprints
    // for every routing policy, on a mixed multi-slot fleet, with and
    // without faults + autoscale, at 1 and 4 worker threads. (Debug
    // builds additionally assert every individual pick against the
    // scan oracle inside `route` itself, so a fingerprint match here is
    // a pick-for-pick match, not a lucky collision.)
    forall(122, 6, |g, _| {
        let seed = 1 + g.usize(1_000_000) as u64;
        let horizon = g.f64(20.0, 35.0);
        let rate = g.f64(4.0, 10.0);
        let pattern = if g.bool() {
            ArrivalPattern::Steady
        } else {
            ArrivalPattern::Bursty
        };
        // mixed rack: multi-slot boards exercise the aux-slot terms of
        // the wait summaries and their explicit rev bumps
        let spec = FleetSpec::new()
            .pattern(pattern)
            .horizon_s(horizon)
            .rate_rps(rate)
            .correlation(0.4)
            .seed(seed)
            .board(BoardSpec::of_class("B4096").slots(2))
            .board(BoardSpec::of_class("B512"))
            .board(BoardSpec::of_class("B1024").slots(1 + g.usize(3)))
            .board(BoardSpec::of_class("B4096"));
        let (cfg0, scenario) = spec.realize().unwrap();
        let faults = g.bool().then(|| {
            if g.bool() {
                FaultProfile::link(seed)
            } else {
                FaultProfile::correlated(seed)
            }
        });
        let autoscale = g.bool().then(AutoscaleConfig::default);
        for routing in [
            RoutingPolicy::SloAware,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::EnergyAware,
            RoutingPolicy::RoundRobin,
        ] {
            let mk = |routing_scan: bool| {
                let cfg = FleetConfig {
                    routing,
                    routing_scan,
                    faults: faults.clone(),
                    autoscale: autoscale.clone(),
                    ..cfg0.clone()
                };
                FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal)).unwrap()
            };
            for threads in [1usize, 4] {
                let scan = mk(true).run_threads(&scenario, threads).unwrap();
                let indexed = mk(false).run_threads(&scenario, threads).unwrap();
                assert_eq!(
                    scan.fingerprint(),
                    indexed.fingerprint(),
                    "{routing:?} x {threads} threads diverged (seed {seed}, \
                     faults {}, autoscale {})",
                    faults.is_some(),
                    autoscale.is_some(),
                );
                // the counters are observability, not physics: the scan
                // run never touches the index, the indexed run serves
                // every arrival through it (round-robin stays on its
                // O(1) cursor walk either way), and neither counter may
                // leak into the fingerprint
                assert_eq!(scan.route_picks, 0, "scan hatch must bypass the index");
                if routing == RoutingPolicy::RoundRobin {
                    assert_eq!(indexed.route_picks, 0, "round-robin never uses the index");
                } else if !scenario.requests.is_empty() {
                    assert!(indexed.route_picks > 0, "indexed run must route via the index");
                }
                assert!(!indexed.fingerprint().contains("route"));
            }
        }
    });
}
