//! Multi-slot board integration contracts (DESIGN.md §16).
//!
//! Three pins:
//! - **K=1 identity** — a fleet with an explicit `slots: vec![1; n]`
//!   must fingerprint byte-identically to the pre-slot path (`slots:
//!   vec![]`) for every RoutingPolicy x FleetPolicy combo at 1 and 4
//!   host threads, and neither run may grow the `:sl=` column.
//! - **Fabric economics** — frames served are invariant in slot count
//!   (extra slots never lose or invent work), per-slot accounting
//!   closes (`sum(slot_served) == requests_done` on every board), and
//!   total energy is strictly monotone in slot count: sibling slots
//!   burn retention power all run, and the shared-fabric cap means
//!   they cannot conjure MAC throughput to pay for it (the
//!   oversubscription inflation factor itself is pinned by the board
//!   kernel's unit tests).
//! - **Thread invariance** — a mixed multi-slot rack under fault
//!   injection + the autoscaler produces one fingerprint for the
//!   single-queue loop and for the sharded executor at every thread
//!   count.

use dpuconfig::coordinator::fleet::{
    parse_fleet_spec, AutoscaleConfig, BoardSpec, FleetConfig, FleetCoordinator, FleetPolicy,
    FleetSpec, RoutingPolicy,
};
use dpuconfig::rl::Baseline;
use dpuconfig::runtime::{default_policy_path, PolicyRuntime};
use dpuconfig::workload::traffic::{ArrivalPattern, FaultProfile};

const ROUTINGS: [RoutingPolicy; 4] = [
    RoutingPolicy::RoundRobin,
    RoutingPolicy::LeastLoaded,
    RoutingPolicy::EnergyAware,
    RoutingPolicy::SloAware,
];

const BASELINES: [Baseline; 4] = [
    Baseline::Optimal,
    Baseline::MaxFps,
    Baseline::MinPower,
    Baseline::Random,
];

/// Acceptance pin: explicit single-slot boards are the pre-slot kernel,
/// bit for bit, for every routing x static-baseline combo at 1 and 4
/// threads. The slot machinery must be invisible when K=1.
#[test]
fn k1_fleets_fingerprint_identically_to_pre_slot_boards() {
    let scenario = FleetSpec::new()
        .pattern(ArrivalPattern::Bursty)
        .boards(3)
        .horizon_s(15.0)
        .rate_rps(6.0)
        .correlation(0.5)
        .seed(11)
        .scenario()
        .unwrap();
    for routing in ROUTINGS {
        for baseline in BASELINES {
            let mk = |slots: Vec<usize>| {
                let cfg = FleetConfig {
                    boards: 3,
                    routing,
                    seed: 11,
                    slots,
                    ..FleetConfig::default()
                };
                FleetCoordinator::new(cfg, FleetPolicy::Static(baseline)).unwrap()
            };
            for threads in [1usize, 4] {
                let base = mk(Vec::new())
                    .run_threads(&scenario, threads)
                    .unwrap()
                    .fingerprint();
                let k1 = mk(vec![1; 3])
                    .run_threads(&scenario, threads)
                    .unwrap()
                    .fingerprint();
                assert_eq!(
                    base, k1,
                    "K=1 drifted from pre-slot: {routing:?} {baseline:?} threads={threads}"
                );
                assert!(
                    !k1.contains(":sl="),
                    "single-slot fleet grew a slot column: {k1}"
                );
            }
        }
    }
}

/// Same identity for the learned-policy arm of FleetPolicy (gated on
/// the committed policy artifact, like the other agent suites).
#[test]
fn k1_identity_holds_for_agent_policy() {
    if !default_policy_path(1).exists() {
        eprintln!("skipping: policy artifact not present");
        return;
    }
    let scenario = FleetSpec::new()
        .pattern(ArrivalPattern::Steady)
        .boards(2)
        .horizon_s(12.0)
        .rate_rps(5.0)
        .correlation(0.5)
        .seed(3)
        .scenario()
        .unwrap();
    let run = |slots: Vec<usize>, threads: usize| {
        let rt = PolicyRuntime::load(&default_policy_path(1), 1).unwrap();
        let cfg = FleetConfig {
            boards: 2,
            routing: RoutingPolicy::EnergyAware,
            seed: 3,
            slots,
            ..FleetConfig::default()
        };
        FleetCoordinator::new(cfg, FleetPolicy::Agent(rt))
            .unwrap()
            .run_threads(&scenario, threads)
            .unwrap()
            .fingerprint()
    };
    for threads in [1usize, 4] {
        assert_eq!(
            run(Vec::new(), threads),
            run(vec![1; 2], threads),
            "agent K=1 drifted at threads={threads}"
        );
    }
}

/// Fabric-contention economics on one B4096-class board: slot count
/// k in {1, 2, 3} serves exactly the same request set (never faster
/// than the shared fabric allows, never dropping work), per-slot
/// accounting closes, and total energy strictly increases with k —
/// idle siblings hold bitstream retention power, so a slot that does
/// not earn its keep shows up on the meter.
#[test]
fn fabric_contention_frames_invariant_energy_monotone_in_slots() {
    let mut last_energy = f64::NEG_INFINITY;
    let mut frames: Option<u64> = None;
    for k in [1usize, 2, 3] {
        let (cfg, scenario) = FleetSpec::new()
            .board(BoardSpec::of_class("B4096").slots(k))
            .pattern(ArrivalPattern::Steady)
            .horizon_s(25.0)
            .rate_rps(3.0)
            .seed(7)
            .routing(RoutingPolicy::RoundRobin)
            .realize()
            .unwrap();
        let r = FleetCoordinator::new(cfg, FleetPolicy::Static(Baseline::Optimal))
            .unwrap()
            .run(&scenario)
            .unwrap();
        assert_eq!(
            r.requests_done() as usize,
            r.requests_total,
            "k={k}: fabric cap must stretch service, never drop frames"
        );
        let b = &r.boards[0];
        assert_eq!(b.slot_served.len(), k);
        assert_eq!(
            b.slot_served.iter().sum::<u64>(),
            b.requests_done,
            "k={k}: per-slot serve accounting does not close: {:?}",
            b.slot_served
        );
        match frames {
            None => frames = Some(r.requests_done()),
            Some(f) => assert_eq!(
                f,
                r.requests_done(),
                "k={k}: served-frame count must be invariant in slot count"
            ),
        }
        let e = r.total_energy_j();
        assert!(
            e > last_energy,
            "k={k}: energy must grow with slot count (retention power), got {e} after {last_energy}"
        );
        last_energy = e;
    }
}

/// Tentpole acceptance: a mixed multi-slot rack (B4096x2, B512,
/// B1024x4) under correlated fault injection and the SLO-pressure
/// autoscaler is byte-identical across executors and thread counts,
/// conserves requests, and reports the slot columns.
#[test]
fn mixed_multi_slot_rack_is_thread_count_invariant_under_faults_and_autoscale() {
    let mut spec = FleetSpec::new()
        .pattern(ArrivalPattern::Bursty)
        .horizon_s(25.0)
        .rate_rps(10.0)
        .correlation(0.6)
        .seed(13)
        .routing(RoutingPolicy::SloAware);
    for b in parse_fleet_spec("B4096x2,B512,B1024x4").unwrap() {
        spec = spec.board(b);
    }
    let (mut cfg, scenario) = spec.realize().unwrap();
    cfg.faults = Some(FaultProfile::correlated(17));
    cfg.autoscale = Some(AutoscaleConfig {
        min_active: 2,
        ..AutoscaleConfig::default()
    });
    let mk = || {
        FleetCoordinator::new(cfg.clone(), FleetPolicy::Static(Baseline::Optimal)).unwrap()
    };
    let base = mk().run(&scenario).unwrap();
    assert_eq!(
        base.requests_done() + base.dropped,
        base.requests_total as u64,
        "conservation broke on the multi-slot rack"
    );
    assert!(
        base.fingerprint().contains(":sl="),
        "multi-slot rack lost its slot column: {}",
        base.fingerprint()
    );
    for threads in [1usize, 2, 4] {
        let fp = mk().run_threads(&scenario, threads).unwrap().fingerprint();
        assert_eq!(
            fp,
            base.fingerprint(),
            "sharded executor drifted from the single queue at threads={threads}"
        );
    }
}
