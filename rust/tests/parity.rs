//! Rust <-> python parity: the rust dpusim and reward implementations must
//! reproduce the python-generated golden vectors bit-for-bit (within 1e-9
//! relative — both sides are f64 with identical expression order).

use dpuconfig::csvutil::Table;
use dpuconfig::data::load_models;
use dpuconfig::dpusim::DpuSim;
use dpuconfig::models::ModelVariant;
use dpuconfig::rl::reward::{Outcome, RewardCalculator};
use dpuconfig::workload::WorkloadState;

fn rel_close(a: f64, b: f64, what: &str) {
    let denom = b.abs().max(1e-30);
    let rel = (a - b).abs() / denom;
    assert!(rel < 1e-9, "{what}: rust {a} vs python {b} (rel {rel:e})");
}

#[test]
fn dpusim_matches_python_golden() {
    let sim = DpuSim::load().unwrap();
    let models = load_models().unwrap();
    let path = dpuconfig::repo_root().join("data").join("golden_parity.csv");
    let t = Table::read(&path).unwrap();
    assert!(t.rows.len() >= 300, "golden grid should be substantial");
    let actions = sim.actions();
    for row in &t.rows {
        let model_name = t.get(row, "model").unwrap();
        let prune = t.get_f64(row, "prune").unwrap();
        let state: WorkloadState = t.get(row, "state").unwrap().parse().unwrap();
        let aid = t.get_usize(row, "action_id").unwrap();
        let base = models.iter().find(|m| m.name == model_name).unwrap();
        let v = ModelVariant::new(base.clone(), prune);
        let a = &actions[aid];
        let m = sim.evaluate(&v, &a.size, a.instances, state).unwrap();
        let ctx = format!("{model_name} PR{} {} {}", prune * 100.0, state, a.notation());
        rel_close(m.latency_ms, t.get_f64(row, "latency_ms").unwrap(), &format!("{ctx} latency"));
        rel_close(m.fps, t.get_f64(row, "fps").unwrap(), &format!("{ctx} fps"));
        rel_close(m.p_fpga, t.get_f64(row, "p_fpga").unwrap(), &format!("{ctx} p_fpga"));
        rel_close(m.p_arm, t.get_f64(row, "p_arm").unwrap(), &format!("{ctx} p_arm"));
        rel_close(m.ppw, t.get_f64(row, "ppw").unwrap(), &format!("{ctx} ppw"));
    }
}

#[test]
fn reward_matches_python_golden() {
    let path = dpuconfig::repo_root().join("data").join("golden_reward.csv");
    let t = Table::read(&path).unwrap();
    let mut rc = RewardCalculator::new();
    for (i, row) in t.rows.iter().enumerate() {
        let r = rc.calculate(&Outcome {
            measured_fps: t.get_f64(row, "fps").unwrap(),
            fpga_power: t.get_f64(row, "power").unwrap(),
            cpu_util: t.get_f64(row, "cpu").unwrap(),
            mem_util_gbs: t.get_f64(row, "mem_gbs").unwrap(),
            gmac: t.get_f64(row, "gmac").unwrap(),
            model_data_mb: t.get_f64(row, "data_mb").unwrap(),
            fps_constraint: 30.0,
        });
        let expected = t.get_f64(row, "reward").unwrap();
        let diff = (r - expected).abs();
        assert!(
            diff < 1e-12,
            "reward step {i}: rust {r} vs python {expected}"
        );
    }
}
