//! Cross-module integration tests: substrate -> featurizer -> baselines ->
//! coordinator -> sweep/eval, without the PJRT artifact (runtime_e2e.rs
//! covers the artifact path).

use dpuconfig::coordinator::{Arrival, Coordinator, Event, Scenario, Selector};
use dpuconfig::data::{load_action_space, load_models};
use dpuconfig::dpusim::{DpuSim, FPS_CONSTRAINT};
use dpuconfig::eval::{fig5, figures, timeline};
use dpuconfig::models::{load_variants, ModelVariant};
use dpuconfig::rl::Baseline;
use dpuconfig::workload::{WorkloadState, ALL_STATES};

#[test]
fn sweep_csv_roundtrips() {
    let sim = DpuSim::load().unwrap();
    let rows = dpuconfig::sweep::run(&sim).unwrap();
    let path = std::env::temp_dir().join("dpuconfig_sweep_test.csv");
    dpuconfig::sweep::write_csv(&rows, &path).unwrap();
    let t = dpuconfig::csvutil::Table::read(&path).unwrap();
    assert_eq!(t.rows.len(), 2574);
    // spot-check a row round-trips numerically
    let r0 = &t.rows[0];
    assert_eq!(t.get(r0, "model").unwrap(), rows[0].model);
    assert_eq!(t.get_f64(r0, "fps").unwrap(), rows[0].fps);
    std::fs::remove_file(&path).ok();
}

#[test]
fn paper_headline_facts_hold_end_to_end() {
    // one test walking the whole §III narrative through the public API
    let sim = DpuSim::load().unwrap();
    let models = load_models().unwrap();
    let m = |n: &str| models.iter().find(|m| m.name == n).unwrap().clone();

    // III-A: optimal depends on the model
    let r152 = ModelVariant::new(m("ResNet152"), 0.0);
    let mob = ModelVariant::new(m("MobileNetV2"), 0.0);
    let a1 = sim.optimal_action(&r152, WorkloadState::None).unwrap();
    let a2 = sim.optimal_action(&mob, WorkloadState::None).unwrap();
    assert_ne!(a1, a2, "different models must prefer different configs");

    // III-B: interference changes the optimum for MobileNetV2
    let a3 = sim.optimal_action(&mob, WorkloadState::Cpu).unwrap();
    assert_ne!(a2, a3, "CPU interference must shift the optimum");

    // III-C: pruning changes the optimum for ResNet152
    let r152_25 = ModelVariant::new(m("ResNet152"), 0.25);
    let a4 = sim.optimal_action(&r152_25, WorkloadState::None).unwrap();
    assert_ne!(a1, a4, "pruning must shift the optimum");
}

#[test]
fn fig5_oracle_vs_static_full_run() {
    let sim = DpuSim::load().unwrap();
    let mut eng = dpuconfig::coordinator::DecisionEngine::new(
        Selector::Static(Baseline::Optimal),
        9,
    );
    let (cases, summaries) =
        fig5::run(&sim, &mut eng, &[WorkloadState::Cpu, WorkloadState::Mem], 9).unwrap();
    assert_eq!(cases.len(), 18);
    assert_eq!(summaries.len(), 2);
    let txt = fig5::render(&cases, &summaries);
    assert!(txt.contains("ResNet152_PR0"));
    assert!(txt.contains("infeasible"));
}

#[test]
fn timeline_reconfigures_between_different_optima() {
    // build a scenario whose two models provably have different optima,
    // then check the coordinator actually reconfigures between them
    let sim = DpuSim::load().unwrap();
    let variants = load_variants().unwrap();
    let st = WorkloadState::None;
    let mut pair = None;
    'outer: for a in &variants {
        for b in &variants {
            let oa = sim.optimal_action(a, st).unwrap();
            let ob = sim.optimal_action(b, st).unwrap();
            if oa != ob {
                pair = Some((a.clone(), b.clone()));
                break 'outer;
            }
        }
    }
    let (a, b) = pair.expect("some pair of models must differ in optimum");
    let scenario = Scenario {
        arrivals: vec![
            Arrival { model: a, at_s: 0.0, duration_s: 10.0 },
            Arrival { model: b, at_s: 10.0, duration_s: 10.0 },
        ],
        workload: vec![(0.0, st)],
        seed: 2,
    };
    let mut c = Coordinator::new(Selector::Static(Baseline::Optimal), 2).unwrap();
    let r = c.run_scenario(&scenario).unwrap();
    assert_eq!(r.totals.reconfigs, 2, "initial load + one switch");
    // the switch decision must carry the full heavy overhead
    let last_decision = r
        .events
        .iter()
        .filter_map(|e| match e {
            Event::Decision { overhead, .. } => Some(overhead),
            _ => None,
        })
        .last()
        .unwrap();
    assert_eq!(last_decision.total_us() / 1000, 999);
}

#[test]
fn fig6_default_scenario_smoke() {
    let r = timeline::run(Selector::Static(Baseline::MinPower), 15.0).unwrap();
    let txt = timeline::render(&r);
    assert!(txt.contains("InceptionV3"));
    assert!(txt.contains("ResNeXt50"));
}

#[test]
fn characterization_tables_cover_every_config_and_model() {
    let sim = DpuSim::load().unwrap();
    let t3 = figures::table_iii(&sim).unwrap();
    assert_eq!(t3.len(), 11);
    for v in load_variants().unwrap() {
        for st in ALL_STATES {
            let bars = figures::bars(&sim, &v, st).unwrap();
            assert_eq!(bars.len(), 26);
            assert_eq!(bars.iter().filter(|b| b.is_best).count(), 1);
            // the best bar respects the constraint when feasible
            let any = bars.iter().any(|b| b.feasible);
            let best = bars.iter().find(|b| b.is_best).unwrap();
            if any {
                assert!(best.feasible, "{} [{}]", v.name(), st.letter());
                assert!(best.fps >= FPS_CONSTRAINT);
            }
        }
    }
}

#[test]
fn action_notations_are_unique_and_well_formed() {
    let actions = load_action_space().unwrap();
    let mut seen = std::collections::HashSet::new();
    for a in &actions {
        assert!(seen.insert(a.notation()), "duplicate {}", a.notation());
        assert!(a.notation().starts_with('B'));
    }
}
