//! Online-adaptation acceptance tests (DESIGN.md §9):
//!
//! * pure-Rust forward pass matches the exported JAX logits on
//!   `data/golden_logits.csv` to 1e-5;
//! * under the calibration-drift scenario the online selector recovers
//!   >= 90% of the drifted oracle's PPW while the frozen agent does not;
//! * the shadow gate never promotes a worse policy (property test);
//! * buffer/GAE invariants;
//! * the serving loop (Selector::Online through the coordinator) and the
//!   fleet (one shared online policy) both close the feedback loop.

use dpuconfig::coordinator::fleet::{FleetConfig, FleetCoordinator, FleetPolicy, FleetSpec};
use dpuconfig::coordinator::{Coordinator, Scenario, Selector};
use dpuconfig::online::buffer::{gae, ReplayBuffer, Transition};
use dpuconfig::online::policy::MlpPolicy;
use dpuconfig::online::session::{self, SessionConfig};
use dpuconfig::online::shadow::{GateConfig, PromotionGate};
use dpuconfig::online::{OnlineAgent, OnlineConfig};
use dpuconfig::rl::features::OBS_DIM;
use dpuconfig::runtime::NUM_ACTIONS;
use dpuconfig::workload::traffic::{ArrivalPattern, DriftKind, DriftProfile};
use dpuconfig::{csvutil::Table, repo_root, testutil};

fn committed_policy() -> MlpPolicy {
    MlpPolicy::load_csv(&repo_root().join("data").join("policy_weights.csv"))
        .expect("data/policy_weights.csv (python -m compile.aot --pin-data)")
}

/// The export-contract parity pin: rust forward == JAX forward to 1e-5.
#[test]
fn forward_matches_jax_goldens_to_1e5() {
    let policy = committed_policy();
    let t = Table::read(&repo_root().join("data").join("golden_logits.csv")).unwrap();
    assert!(!t.rows.is_empty());
    for row in &t.rows {
        let mut obs = [0f32; OBS_DIM];
        for (i, o) in obs.iter_mut().enumerate() {
            *o = t.get_f64(row, &format!("obs_{i}")).unwrap() as f32;
        }
        let f = policy.forward(&obs);
        for j in 0..NUM_ACTIONS {
            let want = t.get_f64(row, &format!("logit_{j}")).unwrap();
            assert!(
                (f.logits[j] - want).abs() < 1e-5,
                "case {}: logit {j} = {} vs jax {} (|d| = {:.2e})",
                row[0],
                f.logits[j],
                want,
                (f.logits[j] - want).abs()
            );
        }
        let want_v = t.get_f64(row, "value").unwrap();
        assert!(
            (f.value - want_v).abs() < 1e-5,
            "case {}: value {} vs jax {}",
            row[0],
            f.value,
            want_v
        );
    }
}

/// THE acceptance scenario: calibration drift (20x leakage growth).
/// The frozen agent's greedy actions fall under 90% of the drifted
/// oracle's PPW; the online agent detects the drift within a few dozen
/// decisions, adapts, promotes, and recovers >= 90% (averaged over two
/// adaptation sessions to keep the stochastic-optimization tail out of
/// the verdict; each session individually must stay far above frozen).
#[test]
fn calibration_drift_adaptation_recovers_oracle_ppw() {
    let mut adapted = Vec::new();
    for seed in [7u64, 11] {
        let cfg = SessionConfig {
            seed,
            ..SessionConfig::default() // 256 pre + 4256 post steps
        };
        let agent = OnlineAgent::new(committed_policy(), cfg.online, cfg.seed);
        let report = session::run_with_agent(&cfg, agent).unwrap();

        assert!(
            report.frozen_ratio < 0.9,
            "drift must invalidate the frozen agent (got {:.3})",
            report.frozen_ratio
        );
        let detected = report.drift_detected_at.expect("drift must be detected");
        assert!(
            detected >= cfg.pre_steps && detected < cfg.pre_steps + 200,
            "detection at step {detected} (drift hits at {})",
            cfg.pre_steps
        );
        assert!(
            report.promoted_at.is_some(),
            "the adapted policy must be promoted: {report:?}"
        );
        assert!(
            report.adapted_ratio >= 0.87,
            "seed {seed}: adapted ratio collapsed ({:.3}, frozen {:.3})",
            report.adapted_ratio,
            report.frozen_ratio
        );
        assert!(report.stats.updates > 0, "training must have run");
        assert_eq!(report.stats.rollbacks, 0, "no rollback on a clean win");
        adapted.push(report.adapted_ratio);
    }
    let mean = adapted.iter().sum::<f64>() / adapted.len() as f64;
    assert!(
        mean >= 0.9,
        "adapted policy must recover >= 90% of the drifted oracle \
         (sessions: {adapted:?})"
    );
}

/// Weaker cross-family guarantee: whatever the drift, the online agent
/// never ends up *worse* than the frozen baseline (the gate only ever
/// switches serving to a windowed winner).
#[test]
fn online_never_loses_to_frozen_across_drift_kinds() {
    for kind in [DriftKind::Thermal, DriftKind::ModelChurn] {
        let cfg = SessionConfig {
            kind,
            magnitude: if kind == DriftKind::Thermal { 1.0 } else { 20.0 },
            post_steps: 1500, // enough to trigger + partially adapt
            ..SessionConfig::default()
        };
        let agent = OnlineAgent::new(committed_policy(), cfg.online, cfg.seed);
        let report = session::run_with_agent(&cfg, agent).unwrap();
        // 0.05 slack: a partial round may promote on a 2% windowed win
        // measured on the noisy visited stream, which can differ a
        // little from the noise-free eval grid
        assert!(
            report.adapted_ratio >= report.frozen_ratio - 0.05,
            "{kind:?}: adapted {:.3} vs frozen {:.3}",
            report.adapted_ratio,
            report.frozen_ratio
        );
    }
}

/// Shadow-promotion safety as a property: across random worse-challenger
/// streams, the gate never promotes.
#[test]
fn gate_never_promotes_a_worse_policy_property() {
    testutil::forall(11, 60, |g, _| {
        let mut gate = PromotionGate::new(GateConfig::default());
        // challenger is worse by a random margin of 5..40%
        let handicap = g.f64(0.05, 0.40);
        let scale = g.f64(1.0, 50.0);
        for _ in 0..300 {
            let inc = scale * (1.0 + 0.02 * g.rng.normal());
            let ch = scale * (1.0 - handicap) * (1.0 + 0.02 * g.rng.normal());
            let e = gate.push(inc.max(1e-3), ch.max(1e-3));
            assert!(e.is_none(), "promoted a {handicap:.2}-worse challenger");
        }
    });
}

/// Buffer and GAE invariants at the integration level.
#[test]
fn buffer_and_gae_invariants() {
    let mut buf = ReplayBuffer::new(64);
    for i in 0..100 {
        buf.push(Transition {
            obs: [i as f32; OBS_DIM],
            action: i % NUM_ACTIONS,
            reward: (i % 7) as f64 - 3.0,
            value: 0.5,
            logp: -1.0,
            done: true,
        });
    }
    assert_eq!(buf.len(), 64, "bounded at capacity");
    let batch = buf.drain();
    assert!(buf.is_empty());
    // single-step episodes: advantage == reward - value, return == reward
    let (adv, ret) = gae(&batch, 123.0, 0.99, 0.95);
    for ((a, r), tr) in adv.iter().zip(ret.iter()).zip(batch.iter()) {
        assert!((a - (tr.reward - tr.value)).abs() < 1e-12);
        assert!((r - tr.reward).abs() < 1e-12);
    }
    // multi-step: advantages must be finite and respect done boundaries
    let episodic: Vec<Transition> = (0..10)
        .map(|i| Transition {
            obs: [0.0; OBS_DIM],
            action: 0,
            reward: 1.0,
            value: 0.0,
            logp: 0.0,
            done: i % 3 == 2,
        })
        .collect();
    let (adv, _) = gae(&episodic, 0.0, 1.0, 1.0);
    assert!((adv[2] - 1.0).abs() < 1e-12, "done stops credit at t=2");
    assert!(adv[0] > adv[2], "within-episode credit accumulates");
}

/// Selector::Online through the real serving loop under a drifting
/// world: the run completes, the loop closes (decisions == feedbacks
/// seen by the agent) and drift is detected.
#[test]
fn serving_loop_closes_the_feedback_loop_under_drift() {
    let scenario =
        Scenario::from_traffic(ArrivalPattern::Steady, 300.0, 2.0, 2.0, 25.0, 11).unwrap();
    let profile = DriftProfile {
        kind: DriftKind::Calibration,
        at_s: 150.0,
        ramp_s: 0.0,
        magnitude: 20.0,
    };
    let agent = OnlineAgent::new(committed_policy(), OnlineConfig::default(), 11);
    let mut online = Coordinator::new(Selector::Online(Box::new(agent)), 11).unwrap();
    let run = online.run_drifted(&scenario, Some(&profile)).unwrap();
    assert!(run.totals.decisions > 100, "{} decisions", run.totals.decisions);
    let stats = *online.engine().online_stats().expect("online selector");
    assert_eq!(
        stats.decisions, run.totals.decisions,
        "every decision must reach the online agent"
    );
    assert!(
        stats.drift_events >= 1,
        "the 20x leakage drift must be detected in the serving loop"
    );

    // and the frozen agent on the same drifted scenario is no better:
    // the online run serves frozen-greedy until a *provably better*
    // challenger is promoted, so its PPW can only match or beat it.
    // (A frozen reference = an online agent whose detectors never fire.)
    let mut frozen = OnlineAgent::new(committed_policy(), OnlineConfig::default(), 11);
    frozen.detector_mut().ph.lambda = f64::INFINITY;
    frozen.detector_mut().obs.threshold = f64::INFINITY;
    let mut frozen_coord = Coordinator::new(Selector::Online(Box::new(frozen)), 11).unwrap();
    // compare on the identical scenario+drift
    let frozen_run = frozen_coord.run_drifted(&scenario, Some(&profile)).unwrap();
    let adapted_ppw = run.totals.avg_ppw();
    let frozen_ppw = frozen_run.totals.avg_ppw();
    assert!(
        adapted_ppw >= frozen_ppw * 0.95,
        "online serving must not lose to frozen: {adapted_ppw:.3} vs {frozen_ppw:.3}"
    );
}

/// One online policy shared across a fleet: every board's decisions come
/// from (and feed) the same agent.
#[test]
fn fleet_shares_one_online_policy() {
    let scenario =
        FleetSpec::new().pattern(ArrivalPattern::Steady).boards(3).horizon_s(60.0).rate_rps(10.0).correlation(0.7).seed(5).scenario().unwrap();
    let cfg = FleetConfig {
        boards: 3,
        seed: 5,
        ..FleetConfig::default()
    };
    let agent = OnlineAgent::new(committed_policy(), OnlineConfig::default(), 5);
    let mut fleet = FleetCoordinator::new(cfg, FleetPolicy::Online(Box::new(agent))).unwrap();
    let report = fleet.run(&scenario).unwrap();
    assert_eq!(report.policy, "online");
    assert!(report.requests_done() > 0);
    let stats = fleet.policy().online_stats().expect("online fleet policy");
    assert_eq!(
        stats.decisions, report.decisions,
        "all boards' decisions flow through the one shared agent"
    );
    // several boards served the stream, yet every decision above flowed
    // through the single shared agent — not N isolated agents
    assert!(report.boards.len() > 1);
}

/// Satellite: data/ and code cannot silently diverge — the committed
/// schema tables must match the compiled-in dimensions.
#[test]
fn data_tables_match_compiled_dimensions() {
    let schema = Table::read(&repo_root().join("data").join("feature_schema.csv")).unwrap();
    assert_eq!(
        schema.rows.len(),
        OBS_DIM,
        "data/feature_schema.csv rows != rl::features::OBS_DIM"
    );
    let actions = Table::read(&repo_root().join("data").join("action_space.csv")).unwrap();
    assert_eq!(
        actions.rows.len(),
        NUM_ACTIONS,
        "data/action_space.csv rows != runtime::NUM_ACTIONS"
    );
    // and the exported weight file carries exactly these dimensions
    let policy = committed_policy();
    assert_eq!(policy.obs_mu.len(), OBS_DIM);
    assert_eq!(policy.b_pi.len(), NUM_ACTIONS);
}
